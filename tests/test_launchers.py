"""CLI driver smoke tests (train / serve / cluster / examples)."""
import json
import subprocess
import sys
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=900):
    out = subprocess.run(
        [sys.executable] + args, capture_output=True, text=True,
        env=ENV, timeout=timeout, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_cluster_driver():
    out = _run(["-m", "repro.launch.cluster", "--windows", "2",
                "--window-size", "8192", "--rounds", "3", "--sample", "512",
                "--workers", "2"])
    rec = json.loads(out[out.index("{"):])
    assert rec["sample_objective"] > 0
    assert rec["rounds_total"] == 6


def test_train_driver_loss_improves():
    import shutil
    # fresh checkpoint dir: the Trainer intentionally resumes from any
    # existing checkpoints (that's the fault-tolerance contract)
    shutil.rmtree(os.path.join(REPO, "checkpoints/_test_train"),
                  ignore_errors=True)
    out = _run(["-m", "repro.launch.train", "--steps", "40", "--batch", "4",
                "--seq", "32", "--ckpt-dir", "checkpoints/_test_train"])
    rec = json.loads(out[out.index("{"):])
    assert rec["status"] == "done"
    # statistical check: training makes progress and never blows up
    assert rec["loss_min"] < rec["loss_first"]
    assert rec["loss_last"] < rec["loss_first"] * 1.05


def test_serve_driver():
    out = _run(["-m", "repro.launch.serve", "--requests", "4", "--slots", "2",
                "--max-tokens", "4", "--prompt-len", "8"])
    rec = json.loads(out[out.index("{"):])
    assert rec["completed"] == 4


def test_cluster_driver_sharded_engine():
    out = _run(["-m", "repro.launch.cluster", "--sharded", "--k", "4",
                "--sample", "256", "--rounds", "4", "--windows", "1",
                "--window-size", "8192"])
    rec = json.loads(out[out.index("{"):])
    assert rec["engine"] == "shard_map"
    assert rec["monotone"] is True
