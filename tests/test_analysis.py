"""Static-analysis suite: fixture true-positives, clean-fixture silence,
CLI/baseline behavior, and the self-check that src/repro stays clean."""
import json
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import all_checks, analyze_file, analyze_source, select_checks
from repro.analysis import baseline as baseline_mod

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "analysis_fixtures"
TAG = re.compile(r"#\s*F:([A-Z]{2}\d{3})")


def expected_findings(path: Path) -> set[tuple[str, int]]:
    out = set()
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for m in TAG.finditer(line):
            out.add((m.group(1), lineno))
    return out


# ---------------------------------------------------------------------------
# fixture-backed true positives / false positives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "name", ["bad_pallas.py", "bad_jit.py", "bad_dtype.py", "bad_obs.py",
             "bad_sharding.py"]
)
def test_fixture_findings_exact(name):
    """Each tagged line yields exactly its finding — code, file and line —
    and nothing else fires anywhere in the fixture."""
    path = FIXTURES / name
    findings = analyze_file(str(path))
    got = {(f.code, f.line) for f in findings}
    assert got == expected_findings(path), [
        f"{f.code}@{f.line}: {f.message}" for f in findings
    ]
    assert all(f.path.endswith(name) for f in findings)


def test_fixture_covers_every_check():
    """The bad_* fixtures jointly exercise every registered check code."""
    tagged = set()
    for p in FIXTURES.glob("bad_*.py"):
        tagged |= {code for code, _ in expected_findings(p)}
    assert tagged == {c.code for c in all_checks()}


def test_clean_fixture_has_no_findings():
    findings = analyze_file(str(FIXTURES / "clean.py"))
    assert findings == [], [f"{f.code}@{f.line}: {f.message}" for f in findings]


def test_select_filters_by_prefix():
    path = FIXTURES / "bad_pallas.py"
    findings = analyze_source(
        path.read_text(), path=str(path), checks=select_checks(["PK002"])
    )
    assert {f.code for f in findings} == {"PK002"}
    with pytest.raises(KeyError):
        select_checks(["ZZ"])


@pytest.mark.parametrize(
    "path",
    [
        "src/repro/launch/cluster.py",
        "src/repro/obs/cli.py",
        "src/repro/obs/__main__.py",
        "benchmarks/run.py",
    ],
)
def test_ob001_exempts_cli_and_benchmark_paths(path):
    findings = analyze_source('print("hello")\n', path=path)
    assert findings == [], [f"{f.code}@{f.line}" for f in findings]


def test_ob001_fires_in_library_paths():
    findings = analyze_source('print("hello")\n', path="src/repro/core/x.py")
    assert {f.code for f in findings} == {"OB001"}


def test_vmem_estimate_details_in_message():
    findings = [
        f
        for f in analyze_file(str(FIXTURES / "bad_pallas.py"))
        if f.code == "PK004"
    ]
    assert len(findings) == 1
    assert "exceeds" in findings[0].message
    assert "MiB" in findings[0].message


# ---------------------------------------------------------------------------
# suppressions: lru_cache factories and inline pragmas
# ---------------------------------------------------------------------------


_JIT_IN_BODY = """
import functools
import jax

def per_call(fn):
    return jax.jit(fn)
"""


def test_jh003_fires_without_suppression():
    findings = analyze_source(_JIT_IN_BODY, path="x.py")
    assert {f.code for f in findings} == {"JH003"}


@pytest.mark.parametrize(
    "deco",
    [
        "functools.lru_cache(maxsize=None)",
        "functools.lru_cache",
        "lru_cache",
        "functools.cache",
    ],
)
def test_jh003_exempts_cached_factories(deco):
    src = _JIT_IN_BODY.replace("def per_call", f"@{deco}\ndef per_call")
    findings = analyze_source(src, path="x.py")
    assert findings == [], [f"{f.code}@{f.line}: {f.message}" for f in findings]


def test_jh003_exempts_nested_function_in_cached_factory():
    src = """
import functools
import jax

@functools.lru_cache(maxsize=None)
def factory(n):
    def build():
        return jax.jit(lambda x: x * n)
    return build()
"""
    assert analyze_source(src, path="x.py") == []


@pytest.mark.parametrize("placement", ["above", "same"])
def test_pragma_suppresses_named_code(placement):
    if placement == "above":
        body = ("    # analysis: allow JH003 — justified here\n"
                "    return jax.jit(fn)")
    else:
        body = "    return jax.jit(fn)  # analysis: allow JH003"
    src = f"import jax\n\ndef per_call(fn):\n{body}\n"
    assert analyze_source(src, path="x.py") == []


def test_pragma_only_suppresses_listed_codes():
    src = ("import jax\n\ndef per_call(fn):\n"
           "    # analysis: allow PK001\n"
           "    return jax.jit(fn)\n")
    findings = analyze_source(src, path="x.py")
    assert {f.code for f in findings} == {"JH003"}


def test_pragma_multiple_codes():
    src = ("import jax\n\ndef per_call(fn):\n"
           "    # analysis: allow PK001, JH003\n"
           "    return jax.jit(fn)\n")
    assert analyze_source(src, path="x.py") == []


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------


def test_baseline_grandfathers_and_detects_new(tmp_path):
    path = FIXTURES / "bad_jit.py"
    findings = analyze_file(str(path))
    assert findings
    bl = tmp_path / "baseline.json"
    baseline_mod.write(str(bl), findings)
    new, old = baseline_mod.split(findings, baseline_mod.load(str(bl)))
    assert new == [] and len(old) == len(findings)
    # a finding not in the baseline stays "new"
    partial = baseline_mod.load(str(bl)) - {findings[0].fingerprint}
    new, _ = baseline_mod.split(findings, partial)
    assert [f.fingerprint for f in new] == [findings[0].fingerprint]


def test_fingerprint_survives_line_shift():
    src = (FIXTURES / "bad_dtype.py").read_text()
    a = analyze_source(src, path="x.py")
    b = analyze_source("# a new comment line\n" + src, path="x.py")
    assert {f.fingerprint for f in a} == {f.fingerprint for f in b}
    assert {f.line for f in a} != {f.line for f in b}


# ---------------------------------------------------------------------------
# CLI + self-check: the repo's own sources stay clean modulo the baseline
# ---------------------------------------------------------------------------


def _run_cli(*args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=REPO, env=env, capture_output=True, text=True,
    )


def test_cli_src_clean_modulo_committed_baseline():
    r = _run_cli("src", "--baseline", "analysis-baseline.json", "-q")
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_fails_without_baseline_on_bad_fixture():
    r = _run_cli(str(FIXTURES / "bad_pallas.py"), "--json")
    assert r.returncode == 1
    doc = json.loads(r.stdout)
    assert doc["summary"]["new"] > 0
    assert doc["summary"]["grandfathered"] == 0
    codes = {f["code"] for f in doc["new"]}
    assert "PK004" in codes
