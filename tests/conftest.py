import os

# Tests run on the single host CPU device; the 512-device override belongs
# ONLY to launch/dryrun.py (sub-process tests set their own XLA_FLAGS).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def blobs(rng):
    """5 tight gaussian blobs in 8-d: global optimum ~ m * d * sigma^2."""
    centers = rng.uniform(-10, 10, size=(5, 8))
    x = np.concatenate(
        [c + rng.normal(scale=0.5, size=(1200, 8)) for c in centers]
    ).astype(np.float32)
    rng.shuffle(x)
    return x
