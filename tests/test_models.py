"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + finiteness; decode == teacher forcing for causal families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import steps as S
from repro.models import model as M


def _batch(cfg, b=2, s=32, seed=0):
    r = np.random.default_rng(seed)
    if cfg.family == "encdec":
        return {
            "frames": jnp.asarray(r.normal(size=(b, s, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(
                r.integers(0, cfg.vocab_size, (b, max(4, s // cfg.dec_ratio)))),
        }
    if cfg.family == "vlm":
        return {
            "img_embeds": jnp.asarray(
                r.normal(size=(b, cfg.img_tokens, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(
                r.integers(0, cfg.vocab_size, (b, s - cfg.img_tokens))),
        }
    return {"tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)))}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, hidden = jax.jit(
        lambda p, b: M.forward(cfg, p, b, remat=False))(params, batch)
    b = batch["tokens"].shape[0]
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one full train step (grad + optimizer update)
    step = jax.jit(S.make_train_step(cfg, grad_accum=1))
    opt_state = step.__wrapped__.optimizer.init(params)
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(params[k]), np.asarray(p2[k]))
        for k in list(params)[:5]
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grad_accum_matches_single_pass(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=4, s=16)
    s1 = jax.jit(S.make_train_step(cfg, grad_accum=1))
    s2 = jax.jit(S.make_train_step(cfg, grad_accum=2))
    o1 = s1.__wrapped__.optimizer.init(params)
    _, _, m1 = s1(params, o1, batch)
    _, _, m2 = s2(params, o1, batch)
    # losses: mean over microbatches == full-batch mean (CE is per-token mean
    # over equal-sized micros)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=5e-2)


@pytest.mark.parametrize(
    "arch", ["qwen3-0.6b", "deepseek-v3-671b", "zamba2-7b", "xlstm-1.3b"]
)
def test_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 16)))
    logits_full, _, _ = jax.jit(
        lambda p, b: M.forward(cfg, p, b, remat=False))(params, {"tokens": toks})
    dc = M.init_cache(cfg, 2, 16)
    dec = jax.jit(lambda p, t, pos, c: M.decode_step(cfg, p, t, pos, c))
    errs = []
    for t in range(16):
        lg, dc = dec(params, toks[:, t : t + 1], jnp.int32(t), dc)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t]))))
    assert max(errs) < 1e-3, errs


def test_prefill_matches_forward_all_archs():
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        lf, _, _ = jax.jit(
            lambda p, b, c=cfg: M.forward(c, p, b, remat=False))(params, batch)
        lp, caches = jax.jit(lambda p, b, c=cfg: M.prefill(c, p, b))(params, batch)
        np.testing.assert_allclose(
            np.asarray(lp[:, 0], np.float32),
            np.asarray(lf[:, -1], np.float32),
            rtol=2e-3, atol=2e-3, err_msg=arch,
        )


def test_window_attention_masks_past():
    """gemma3 local layers: tokens beyond the window must not influence."""
    from repro.models import attention as A

    cfg = get_config("gemma3-4b", smoke=True)
    r = np.random.default_rng(0)
    q = jnp.asarray(r.normal(size=(1, 24, 2, 8)), jnp.float32)
    k = jnp.asarray(r.normal(size=(1, 24, 2, 8)), jnp.float32)
    v = jnp.asarray(r.normal(size=(1, 24, 2, 8)), jnp.float32)
    out1 = A.causal_attention(q, k, v, q_chunk=8, window=4)
    # perturb a key/value far in the past of the last query
    k2 = k.at[:, 0].add(100.0)
    v2 = v.at[:, 0].add(100.0)
    out2 = A.causal_attention(q, k2, v2, q_chunk=8, window=4)
    np.testing.assert_allclose(
        np.asarray(out1[:, -1]), np.asarray(out2[:, -1]), rtol=1e-5, atol=1e-5
    )
    # but it must influence position 0..3
    assert not np.allclose(np.asarray(out1[:, 1]), np.asarray(out2[:, 1]))


def test_moe_dropless_at_high_capacity():
    """With capacity_factor = E/top_k the sort-dispatch must drop nothing:
    outputs equal the dense (loop over experts) reference."""
    from repro.models import moe as moe_mod

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    from repro.models.common import init_from_table
    params = init_from_table(jax.random.PRNGKey(0), moe_mod.moe_table(cfg),
                             jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, cfg.d_model)),
                    jnp.float32)
    y, aux = moe_mod.moe_forward(params, x, cfg,
                                 capacity_factor=cfg.n_experts / cfg.top_k)
    # dense reference
    logits = x.reshape(-1, cfg.d_model) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    topw, ids = jax.lax.top_k(probs, cfg.top_k)
    w = topw / topw.sum(-1, keepdims=True)
    xt = x.reshape(-1, cfg.d_model)
    out = np.zeros_like(np.asarray(xt))
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ params["wg"][e]) * (xt @ params["wu"][e])
        ye = np.asarray(h @ params["wd"][e])
        for slot in range(cfg.top_k):
            mask = np.asarray(ids[:, slot]) == e
            out[mask] += np.asarray(w[:, slot])[mask, None] * ye[mask]
    np.testing.assert_allclose(
        np.asarray(y).reshape(-1, cfg.d_model), out, rtol=2e-3, atol=2e-3
    )


def test_cell_applicability_rules():
    assert not S.cell_is_applicable(get_config("qwen3-0.6b"), "long_500k")
    assert S.cell_is_applicable(get_config("zamba2-7b"), "long_500k")
    assert S.cell_is_applicable(get_config("xlstm-1.3b"), "long_500k")
    assert S.cell_is_applicable(get_config("gemma3-4b"), "long_500k")
    assert not S.cell_is_applicable(get_config("whisper-medium"), "long_500k")
