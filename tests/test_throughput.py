"""Streaming throughput engine: prefetch, donation, autotune, bf16.

The performance layers added for docs/performance.md must be *invisible* to
results: prefetch on/off and donation on/off are bit-identical; autotune only
changes tile choices (padding makes every tile numerically exact); bf16 is
opt-in and bounded. These tests pin those contracts plus the machinery
itself (donation actually aliases buffers, the autotune cache round-trips,
the ragged objective tail no longer retraces).

    PYTHONPATH=src JAX_PLATFORMS=cpu pytest tests/test_throughput.py -q
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import flags
from repro.core import HPClust, HPClustConfig
from repro.core import hpclust as hp_mod
from repro.core import strategies
from repro.data import device_stream
from repro.data.pipeline import blob_stream
from repro.kernels import autotune, ops

CFG = HPClustConfig(k=4, sample_size=256, workers=2, rounds=3)


def _windows(n=3, m=2048, d=8, seed=0):
    gen = blob_stream(m, n=d, k=4, seed=seed)
    return [np.asarray(next(gen), np.float32) for _ in range(n)]


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------


def _state(cfg=CFG, d=8, seed=0):
    return strategies.init_state(jax.random.PRNGKey(seed), cfg, d)


def test_donated_runner_lowering_aliases_output():
    data = jnp.asarray(_windows(1)[0])
    lowered = hp_mod._jit_run_from_state_donated.lower(
        _state(), data, cfg=CFG)
    # jax 0.4.37's donation marker in StableHLO: input aliased to an output.
    assert "tf.aliasing_output" in lowered.as_text()
    plain = hp_mod._jit_run_from_state.lower(_state(), data, cfg=CFG)
    assert "tf.aliasing_output" not in plain.as_text()


def test_donation_deletes_input_and_matches_copying_path():
    data = jnp.asarray(_windows(1)[0])
    s_copy, s_don = _state(), _state()
    out_copy, _ = hp_mod._jit_run_from_state(s_copy, data, cfg=CFG)
    out_don, _ = hp_mod._jit_run_from_state_donated(s_don, data, cfg=CFG)
    for a, b in zip(jax.tree_util.tree_leaves(out_copy),
                    jax.tree_util.tree_leaves(out_don)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s_don.centroids.is_deleted()     # buffers were really donated
    assert not s_copy.centroids.is_deleted()


def test_fit_stream_bit_identical_across_prefetch_and_donation(monkeypatch):
    wins = _windows(3)
    results = []
    for prefetch, donate in ((0, "0"), (0, "1"), (2, "0"), (3, "1")):
        monkeypatch.setenv("REPRO_DONATE", donate)
        r = HPClust(CFG, seed=7, prefetch=prefetch).fit_stream(iter(wins))
        results.append(r)
    ref = results[0]
    for r in results[1:]:
        np.testing.assert_array_equal(r.centroids, ref.centroids)
        np.testing.assert_array_equal(r.history, ref.history)
        assert r.objective == ref.objective


def test_checkpoint_resume_bitforbit_with_donation_on(monkeypatch, tmp_path):
    from repro.resilience import chaos

    monkeypatch.setenv("REPRO_DONATE", "1")
    wins = _windows(4)
    full = HPClust(CFG, seed=3).fit_stream(iter(wins))

    # Crash at window 2: the pre-donation host snapshot must keep the
    # crash-save checkpoint readable (donation deletes the device buffers).
    with pytest.raises(chaos.ChaosError):
        HPClust(CFG, seed=3).fit_stream(
            chaos.crash_stream(iter(wins), at_window=2),
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
        )
    resumed = HPClust(CFG, seed=3).fit_stream(
        iter(wins), checkpoint_dir=str(tmp_path), resume=True)
    np.testing.assert_array_equal(resumed.centroids, full.centroids)
    assert resumed.objective == full.objective


# ---------------------------------------------------------------------------
# prefetch
# ---------------------------------------------------------------------------


def test_device_stream_matches_sync_path():
    wins = _windows(3)
    wins[1][5] = np.nan  # one row for sanitize to repair
    sync = list(device_stream(iter(wins), depth=0))
    pref = list(device_stream(iter(wins), depth=2))
    assert [i.index for i in pref] == [i.index for i in sync]
    for a, b in zip(pref, sync):
        np.testing.assert_array_equal(a.host, b.host)
        np.testing.assert_array_equal(
            np.asarray(a.device), np.asarray(b.device))
        assert a.n_bad == b.n_bad


def test_device_stream_start_at_skips_without_preparing():
    wins = _windows(4)
    got = list(device_stream(iter(wins), depth=2, start_at=2))
    assert [i.index for i in got] == [2, 3]


def test_device_stream_reraises_original_exception():
    class Boom(RuntimeError):
        pass

    def gen():
        yield _windows(1)[0]
        raise Boom("producer died")

    with pytest.raises(Boom, match="producer died"):
        list(device_stream(gen(), depth=2))


def test_device_stream_flags_in_pull_order_and_stops():
    pulls = {"n": 0}
    wins = _windows(5)

    def gen():
        for w in wins:
            pulls["n"] += 1
            yield w

    # Preemption fires when the 3rd window is pulled; with depth 4 the
    # producer could run far ahead, but the flag must still land on index 2
    # and production must stop there.
    got = list(device_stream(
        gen(), depth=4, flag_fn=lambda: pulls["n"] >= 3))
    assert [i.flagged for i in got] == [False, False, True]
    assert pulls["n"] == 3


# ---------------------------------------------------------------------------
# autotune
# ---------------------------------------------------------------------------


def test_autotune_off_is_default_and_returns_none(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert flags.autotune_mode() == "off"
    assert autotune.lookup("assign", 4096, 16, 64) is None


def test_autotune_cache_roundtrip_and_corrupt_fallback(monkeypatch, tmp_path):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.invalidate_memory_cache()
    key = autotune.cache_key("assign", 4096, 16, 64, backend="cpu")
    autotune._store(str(path), key, (256, 128, 128), 123.4)
    autotune.invalidate_memory_cache()
    assert autotune.lookup("assign", 4096, 16, 64, backend="cpu") == (
        256, 128, 128)
    # Bucketing: a nearby shape maps to the same entry.
    assert autotune.cache_key("assign", 3000, 16, 64, backend="cpu") == key
    # Corrupt cache file == empty cache == heuristic fallback, no raise.
    path.write_text("{not json")
    autotune.invalidate_memory_cache()
    assert autotune.lookup("assign", 4096, 16, 64, backend="cpu") is None
    autotune.invalidate_memory_cache()


def test_autotune_candidates_fit_budget_and_alignment():
    cands = autotune.candidates("assign", 4096, 16, 64)
    assert cands
    for bs, bk, bd in cands:
        assert bs % 8 == 0 and bk % 128 == 0 and bd % 128 == 0
        assert autotune.vmem_bytes(
            "assign", bs, bk, bd) <= autotune.VMEM_BUDGET_BYTES


def test_autotune_probe_persists_and_results_stay_exact(monkeypatch, tmp_path):
    path = tmp_path / "autotune.json"
    # A shape no other test compiles: block choice happens at TRACE time, so
    # probing needs a cold jit-cache entry for this (shape, impl) pair.
    x = np.asarray(_windows(1, m=301, d=24)[0])
    rng = np.random.default_rng(1)
    c = np.asarray(rng.normal(size=(6, 24)), np.float32)

    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    ref_idx, ref_d2 = ops.assign_clusters(
        jnp.asarray(x), jnp.asarray(c), impl="ref")

    monkeypatch.setenv("REPRO_AUTOTUNE", "probe")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.invalidate_memory_cache()
    try:
        idx, d2 = ops.assign_clusters(
            jnp.asarray(x), jnp.asarray(c), impl="interpret")
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
        # ref reduces in a different order than the tiled kernel: ulp-level
        # drift is expected, tile choice must not add more than that.
        np.testing.assert_allclose(
            np.asarray(d2), np.asarray(ref_d2), rtol=1e-5)
        blob = json.loads(path.read_text())
        assert blob["version"] == 1
        [(key, entry)] = [(k, v) for k, v in blob["entries"].items()
                          if "/assign/" in k]
        assert len(entry["blocks"]) == 3 and entry["us"] > 0
    finally:
        autotune.invalidate_memory_cache()


# ---------------------------------------------------------------------------
# bf16 compute dtype
# ---------------------------------------------------------------------------


def test_bf16_assign_matches_f32_within_tolerance():
    x = jnp.asarray(_windows(1, m=300, d=16)[0])
    c = jnp.asarray(
        np.random.default_rng(2).normal(size=(5, 16)), jnp.float32)
    i32, d32 = ops.assign_clusters(x, c, impl="interpret")
    i16, d16 = ops.assign_clusters(
        x, c, impl="interpret", compute_dtype="bf16")
    agree = float(np.mean(np.asarray(i32) == np.asarray(i16)))
    assert agree >= 0.99  # ties may flip under bf16 rounding
    np.testing.assert_allclose(
        np.asarray(d16), np.asarray(d32), rtol=2e-2, atol=2e-2)


def test_bf16_lloyd_counts_accumulate_in_f32():
    # 3000 rows into one cluster would saturate a bf16 count (max 256 steps
    # of +1 at 256); f32 accumulation must count exactly.
    x = jnp.asarray(np.zeros((3000, 8), np.float32))
    c = jnp.asarray(np.stack([np.zeros(8), np.full(8, 100.0)]), jnp.float32)
    _, _, _, counts = ops.lloyd_pass(x, c, impl="interpret",
                                     compute_dtype="bf16")
    np.testing.assert_array_equal(np.asarray(counts), [3000.0, 0.0])


# ---------------------------------------------------------------------------
# ragged objective tail
# ---------------------------------------------------------------------------


def test_objective_tail_batch_does_not_retrace():
    hp = HPClust(CFG, seed=0)
    c = np.asarray(
        np.random.default_rng(3).normal(size=(4, 8)), np.float32)
    rng = np.random.default_rng(4)
    batch = 512
    full = np.asarray(rng.normal(size=(batch, 8)), np.float32)
    v_full = hp.objective(full, c, batch=batch)

    before = ops._mssc_objective_jit._cache_size()
    for tail in (1, 17, 300):  # three different ragged tails
        hp.objective(
            np.asarray(rng.normal(size=(batch + tail, 8)), np.float32),
            c, batch=batch)
    # Padding pins the shapes to (batch, d) + the (1, d) probe: at most those
    # two new entries total, NOT one per tail length.
    assert ops._mssc_objective_jit._cache_size() - before <= 2

    # And the padded value equals the unpadded math.
    tail_rows = np.asarray(rng.normal(size=(3, 8)), np.float32)
    both = np.concatenate([full, tail_rows])
    expect = v_full + hp.objective(tail_rows, c, batch=batch)
    assert hp.objective(both, c, batch=batch) == pytest.approx(
        expect, rel=1e-5)
