"""Chaos harness: fault injection against the resilience layer.

Each test injects a deterministic fault (repro.resilience.chaos) and asserts
the stack degrades the way docs/resilience.md promises: crashes resume
bit-for-bit, poisoned workers are quarantined instead of winning argmins,
corrupt windows are sanitized and counted, dying prefetch producers restart
with backoff, and checkpoint writers never corrupt the previous checkpoint.

Run separately from tier-1 (CI job: chaos):
    PYTHONPATH=src JAX_PLATFORMS=cpu pytest tests/test_resilience.py -q
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import HPClust, HPClustConfig
from repro.core import strategies
from repro.core.hpclust import stream_from_generator
from repro.data import PipelineError, blob_stream, prefetch_iter
from repro.resilience import (
    Deadline,
    PreemptionGuard,
    RetryError,
    RetryPolicy,
    backoff_delays,
    retry_call,
    sanitize_window,
)
from repro.resilience import chaos
from repro.resilience.chaos import ChaosError


# ---------------------------------------------------------------------------
# retry / backoff
# ---------------------------------------------------------------------------


def test_backoff_deterministic_and_capped():
    pol = RetryPolicy(base_delay=0.05, max_delay=0.4, multiplier=2.0)
    a = list(itertools.islice(backoff_delays(pol, seed=7), 8))
    b = list(itertools.islice(backoff_delays(pol, seed=7), 8))
    assert a == b
    assert all(0.0 <= d <= 0.4 * (1 + pol.jitter) for d in a)


def test_retry_call_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise ValueError("boom")
        return "ok"

    out = retry_call(flaky, policy=RetryPolicy(max_attempts=5),
                     sleep=lambda s: None)
    assert out == "ok" and calls["n"] == 3


def test_retry_call_exhausts_with_cause():
    with pytest.raises(RetryError) as ei:
        retry_call(lambda: 1 / 0, policy=RetryPolicy(max_attempts=2),
                   sleep=lambda s: None)
    assert isinstance(ei.value.__cause__, ZeroDivisionError)


def test_deadline_fake_clock():
    t = [0.0]
    dl = Deadline(1.5, clock=lambda: t[0])
    assert not dl.expired and dl.remaining() == pytest.approx(1.5)
    t[0] = 2.0
    assert dl.expired and dl.remaining() == 0.0


# ---------------------------------------------------------------------------
# prefetch supervision
# ---------------------------------------------------------------------------


def test_prefetch_restarts_through_producer_deaths():
    def src():
        yield from range(10)

    factory = chaos.failing_source(src, fail_at=[3, 7])
    got = list(prefetch_iter(factory, size=2, max_restarts=3, poll_s=0.05,
                             sleep=lambda s: None))
    # Restarts re-run the factory from scratch (duplicates allowed); the
    # tail of the range must eventually arrive.
    assert got[-1] == 9
    assert set(got) == set(range(10))


def test_prefetch_raises_after_restart_budget():
    def dead():
        raise ChaosError("dead on arrival")
        yield  # pragma: no cover

    with pytest.raises(PipelineError) as ei:
        list(prefetch_iter(lambda: dead(), size=1, max_restarts=2,
                           poll_s=0.05, sleep=lambda s: None))
    assert isinstance(ei.value.__cause__, ChaosError)


def test_prefetch_finite_stream_completes_cleanly():
    def src():
        yield from range(5)

    assert list(prefetch_iter(src, size=2, poll_s=0.05)) == list(range(5))


# ---------------------------------------------------------------------------
# window sanitization
# ---------------------------------------------------------------------------


def test_sanitize_window_preserves_shape_and_counts():
    x = np.arange(20, dtype=np.float32).reshape(5, 4)
    x[1, 2] = np.nan
    x[3, 0] = np.inf
    out, n_bad = sanitize_window(x)
    assert n_bad == 2
    assert out.shape == x.shape and out.dtype == np.float32
    assert np.isfinite(out).all()
    # good rows untouched
    np.testing.assert_array_equal(out[0], x[0])


def test_sanitize_window_all_bad_and_bad_rank():
    out, n_bad = sanitize_window(np.full((4, 3), np.nan, np.float32))
    assert out is None and n_bad == 4
    with pytest.raises(ValueError):
        sanitize_window(np.zeros((4,), np.float32))


def test_stream_sanitization_counts_and_keeps_centroids_finite():
    cfg = HPClustConfig(k=4, sample_size=256, workers=2, rounds=2)
    hp = HPClust(cfg, seed=0)
    at = {1: 0.25}
    win = 2048

    def stream():
        return stream_from_generator(blob_stream(win, n=5, k=4, seed=3), 3)

    res = hp.fit_stream(chaos.corrupt_stream(stream(), at=at, mode="nan"))
    assert res.stats.sanitized_rows == chaos.corrupted_rows(at, win)
    assert np.isfinite(res.centroids).all()
    assert np.isfinite(res.objective)
    # sanitization must not change shape-keyed jit cache entries: clean run
    # over the same source also succeeds and is at least as good as random
    clean = HPClust(cfg, seed=0).fit_stream(stream())
    assert np.isfinite(clean.objective)


# ---------------------------------------------------------------------------
# crash / preempt / resume (acceptance: resumed <= uninterrupted + 1e-5)
# ---------------------------------------------------------------------------

_STREAM_CFG = HPClustConfig(k=4, sample_size=256, workers=2, rounds=3)


def _stream(n_windows=4):
    return stream_from_generator(blob_stream(4096, n=5, k=4, seed=7),
                                 n_windows)


def test_crash_midstream_then_resume_matches_uninterrupted(tmp_path):
    res0 = HPClust(_STREAM_CFG, seed=0).fit_stream(_stream())

    with pytest.raises(ChaosError):
        HPClust(_STREAM_CFG, seed=0).fit_stream(
            chaos.crash_stream(_stream(), at_window=2),
            checkpoint_dir=str(tmp_path),
        )
    res1 = HPClust(_STREAM_CFG, seed=0).fit_stream(
        _stream(), checkpoint_dir=str(tmp_path), resume=True
    )
    assert res1.stats.resumed_at == 2
    assert res1.objective <= res0.objective + 1e-5
    # deterministic source + checkpointed PRNG keys => bit-for-bit replay
    np.testing.assert_allclose(res1.history, res0.history)
    np.testing.assert_allclose(res1.centroids, res0.centroids)


def test_preempt_checkpoints_and_resumes(tmp_path):
    guard = PreemptionGuard()
    r1 = HPClust(_STREAM_CFG, seed=0).fit_stream(
        chaos.preempt_stream(_stream(), at_window=2, guard=guard),
        checkpoint_dir=str(tmp_path), preemption_guard=guard,
    )
    assert r1.stats.preempted and r1.stats.windows == 2
    r2 = HPClust(_STREAM_CFG, seed=0).fit_stream(
        _stream(), checkpoint_dir=str(tmp_path), resume=True
    )
    full = HPClust(_STREAM_CFG, seed=0).fit_stream(_stream())
    assert r2.objective <= full.objective + 1e-5


def test_resume_requires_checkpoint_dir():
    with pytest.raises(ValueError):
        HPClust(_STREAM_CFG, seed=0).fit_stream(_stream(), resume=True)


def test_empty_stream_raises():
    with pytest.raises(ValueError):
        HPClust(_STREAM_CFG, seed=0).fit_stream(iter(()))


def test_crashing_checkpoint_manager_preserves_previous(tmp_path):
    m = chaos.CrashingCheckpointManager(tmp_path, crash_at_steps=[2])
    tree = {"a": np.ones(4, np.float32)}
    m.save(1, tree)
    with pytest.raises(ChaosError):
        m.save(2, {"a": np.zeros(4, np.float32)})
    step, restored = m.restore(tree)
    assert step == 1 and np.allclose(restored["a"], 1.0)
    m.save(2, tree)  # one-shot crash: retry succeeds
    assert m.latest_step() == 2


# ---------------------------------------------------------------------------
# poisoned-worker quarantine (acceptance: NaN worker never becomes the base)
# ---------------------------------------------------------------------------

_COOP_CFG = HPClustConfig(k=4, sample_size=256, workers=4, rounds=3,
                          strategy="cooperative")


def _fitted_state(cfg=_COOP_CFG, seed=1):
    data = jnp.asarray(next(blob_stream(4096, n=5, k=4, seed=seed)))
    state = strategies.init_state(jax.random.PRNGKey(0), cfg, 5)
    state, _ = strategies.run_rounds(state, data, cfg)
    return state, data


@pytest.mark.parametrize("mode", ["nan_obj", "neginf_obj"])
def test_poisoned_worker_never_selected_as_base(mode):
    state, _ = _fitted_state()
    healthy_best = int(jnp.argmin(state.best_obj))
    poisoned = (healthy_best + 1) % _COOP_CFG.workers
    ps = chaos.poison_state(state, [poisoned], mode=mode)

    base_c, _ = strategies._select_base(ps, jnp.bool_(True), _COOP_CFG)
    # every worker warm-starts from the healthy best, not the poisoned one
    np.testing.assert_allclose(
        np.asarray(base_c), np.asarray(state.centroids[healthy_best])[None]
        .repeat(_COOP_CFG.workers, axis=0)
    )
    c, obj = strategies.best_of(ps)
    assert np.isfinite(float(obj))
    np.testing.assert_allclose(np.asarray(c),
                               np.asarray(state.centroids[healthy_best]))


@pytest.mark.parametrize("mode", ["nan_obj", "neginf_obj", "nan_centroids"])
def test_quarantine_flags_and_recovers(mode):
    state, data = _fitted_state()
    ps = chaos.poison_state(state, [0], mode=mode)
    st2, m2 = strategies.run_rounds(ps, data, _COOP_CFG)
    q0 = np.asarray(m2.quarantined[0])
    assert q0[0] and not q0[1:].any()
    assert np.isfinite(np.asarray(st2.best_obj)).all()
    assert np.isfinite(np.asarray(m2.best_obj)).all()
    assert np.isfinite(np.asarray(st2.centroids)).all()


def test_quarantine_all_workers_poisoned_recovers():
    state, data = _fitted_state()
    ps = chaos.poison_state(state, range(_COOP_CFG.workers),
                            mode="nan_centroids")
    st2, m2 = strategies.run_rounds(ps, data, _COOP_CFG)
    assert np.asarray(m2.quarantined[0]).all()
    assert np.isfinite(np.asarray(st2.best_obj)).all()


def test_quarantine_is_noop_on_healthy_state():
    state, _ = _fitted_state()
    st2, bad = strategies.quarantine_nonfinite(state)
    assert not np.asarray(bad).any()
    np.testing.assert_array_equal(np.asarray(st2.centroids),
                                  np.asarray(state.centroids))


# ---------------------------------------------------------------------------
# trainer + checkpoint satellites
# ---------------------------------------------------------------------------


def _toy_trainer(tmp_path, **cfg_kw):
    from repro.runtime import Trainer, TrainerConfig

    def step_fn(p, o, b):
        return p + 1, o, {"loss": float(p)}

    def init_state():
        return np.float32(0.0), np.float32(0.0)

    def data():
        while True:
            yield {}

    cfg = TrainerConfig(total_steps=5, ckpt_dir=str(tmp_path), **cfg_kw)
    return Trainer(cfg, step_fn, init_state, data())


def test_trainer_step0_preemption_writes_no_negative_checkpoint(tmp_path):
    tr = _toy_trainer(tmp_path)
    tr.preempt()
    out = tr.run()
    assert out["status"] == "preempted" and out["step"] == 0
    assert not [p.name for p in tmp_path.iterdir() if "-" in p.name]
    assert CheckpointManager(tmp_path).all_steps() == []


def test_trainer_midrun_preemption_still_checkpoints(tmp_path):
    tr = _toy_trainer(tmp_path, ckpt_every=100)
    orig = tr.step_fn

    def step_then_preempt(p, o, b):
        if float(p) >= 2:
            tr.preempt()
        return orig(p, o, b)

    tr.step_fn = step_then_preempt
    out = tr.run()
    assert out["status"] == "preempted" and out["step"] == 3
    assert CheckpointManager(tmp_path).latest_step() == 2


def test_blocking_save_joins_inflight_async_writer(tmp_path):
    m = CheckpointManager(tmp_path, keep=2, async_save=True)
    tree = {"a": np.arange(8, dtype=np.float32)}
    for s in range(5):
        m.save(s, tree, block=False)
    m.save(5, tree)  # must join the in-flight writer, never race it
    m.wait()
    assert m.latest_step() == 5
    step, restored = m.restore(tree)
    assert step == 5 and np.allclose(restored["a"], tree["a"])


# ---------------------------------------------------------------------------
# serving engine satellites
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_parts():
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("qwen3-0.6b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk_engine(engine_parts, **kw):
    from repro.serving.engine import ServeEngine

    cfg, params = engine_parts
    return ServeEngine(cfg, params, slots=2, max_len=64, **kw)


def _req(rid, **kw):
    from repro.serving.engine import Request

    return Request(rid=rid, prompt=np.arange(1, 5, dtype=np.int32),
                   max_tokens=3, **kw)


def test_engine_run_returns_completed_requests(engine_parts):
    eng = _mk_engine(engine_parts)
    reqs = [_req(i) for i in range(3)]
    done = eng.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done and not r.timed_out for r in done)
    assert all(len(r.out) == 3 for r in done)


def test_engine_bounded_admission(engine_parts):
    from repro.serving.engine import AdmissionError

    eng = _mk_engine(engine_parts, max_queue=1)
    eng.submit(_req(0))
    with pytest.raises(AdmissionError):
        eng.submit(_req(1))


def test_engine_deadline_marks_timed_out(engine_parts):
    t = [0.0]
    eng = _mk_engine(engine_parts, clock=lambda: t[0])
    late = _req(0, deadline_s=0.5)
    eng.submit(late)
    t[0] = 1.0  # deadline passes while queued
    done = eng.run([_req(1)])
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].timed_out and by_rid[0].done
    assert not by_rid[1].timed_out and len(by_rid[1].out) == 3
