"""repro.obs: zero-overhead no-op mode, span nesting, metric thread-safety,
JSONL round-trip through the CLI summarizer, and traced-fit integration."""
import io
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import cli as obs_cli


@pytest.fixture
def recorder():
    """Fresh Recorder + ListSink with a deterministic clock; always restores
    whatever recorder was installed before the test."""
    sink = obs.ListSink()
    ticks = iter(float(i) for i in range(10_000))
    rec = obs.Recorder((sink,), clock=lambda: next(ticks))
    prev = obs.set_recorder(rec)
    yield rec, sink
    obs.set_recorder(prev)


def spans_of(sink):
    return [r for r in sink.records if r["type"] == "span"]


# ---------------------------------------------------------------------------
# no-op mode
# ---------------------------------------------------------------------------


def test_disabled_mode_returns_null_span_singleton():
    prev = obs.set_recorder(None)
    try:
        assert obs.get_recorder() is None
        assert not obs.enabled()
        # Identity, not just type: the disabled path must allocate nothing.
        s1 = obs.span("hot.loop", i=0)
        s2 = obs.span("hot.loop", i=1)
        assert s1 is obs.NULL_SPAN and s2 is obs.NULL_SPAN
        with s1 as inner:
            assert inner is obs.NULL_SPAN
            assert inner.set(rows=5) is obs.NULL_SPAN
        # Metric/event helpers are silent no-ops.
        obs.inc("c")
        obs.gauge("g", 1.0)
        obs.observe("h", 0.5)
        obs.event("e", a=1)
        obs.flush()
    finally:
        obs.set_recorder(prev)


def test_shutdown_without_recorder_is_safe():
    prev = obs.set_recorder(None)
    try:
        obs.shutdown()
    finally:
        obs.set_recorder(prev)


# ---------------------------------------------------------------------------
# span semantics
# ---------------------------------------------------------------------------


def test_span_nesting_parent_ids_and_durations(recorder):
    rec, sink = recorder
    with obs.span("outer") as outer:
        with obs.span("inner"):
            pass
        outer.set(note="x")
    spans = spans_of(sink)
    # Children close (and emit) before parents.
    assert [s["name"] for s in spans] == ["inner", "outer"]
    inner, outer_rec = spans
    assert outer_rec["parent_id"] is None
    assert inner["parent_id"] == outer_rec["span_id"]
    assert inner["run"] == outer_rec["run"] == rec.run
    # Fake clock ticks once per enter/exit: inner dur 1 tick, outer 3.
    assert inner["dur"] == 1.0
    assert outer_rec["dur"] == 3.0
    assert outer_rec["attrs"] == {"note": "x"}


def test_span_records_error_attr_and_propagates(recorder):
    _, sink = recorder
    with pytest.raises(ValueError):
        with obs.span("failing"):
            raise ValueError("boom")
    (span,) = spans_of(sink)
    assert span["attrs"]["error"] == "ValueError"


def test_span_stacks_are_thread_local(recorder):
    rec, sink = recorder
    rec.clock = __import__("time").monotonic  # real clock: threads interleave
    barrier = threading.Barrier(2)

    def worker(name):
        with rec.span(name):
            barrier.wait(timeout=5)

    threads = [threading.Thread(target=worker, args=(f"w{i}",)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = spans_of(sink)
    assert len(spans) == 2
    # Concurrent sibling spans on different threads are both roots.
    assert all(s["parent_id"] is None for s in spans)
    assert {s["thread"] for s in spans} != {spans[0]["thread"]} or \
        spans[0]["thread"] != spans[1]["thread"]


def test_distinct_recorders_have_distinct_run_tokens():
    a, b = obs.Recorder(()), obs.Recorder(())
    assert a.run != b.run


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metric_registry_thread_safety():
    reg = obs.MetricRegistry()
    n_threads, n_iters = 8, 500

    def worker(i):
        for j in range(n_iters):
            reg.counter("c").add(1)
            reg.gauge("g").set(i)
            reg.histogram("h").observe(j)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    assert snap["counters"]["c"] == n_threads * n_iters
    assert snap["histograms"]["h"]["count"] == n_threads * n_iters
    assert 0 <= snap["gauges"]["g"] < n_threads


def test_metric_kind_mismatch_raises():
    reg = obs.MetricRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_histogram_caps_values_but_not_count():
    from repro.obs import core as obs_core

    h = obs.Histogram("h")
    n = obs_core._VALUES_CAP + 100
    for i in range(n):
        h.observe(float(i))
    snap = h.snapshot()
    assert snap["count"] == n
    assert snap["max"] == float(n - 1)
    assert len(snap["values"]) == obs_core._VALUES_CAP


def test_quantile_nearest_rank():
    vals = [float(i) for i in range(1, 101)]
    assert obs.quantile(vals, 0.0) == 1.0
    assert obs.quantile(vals, 1.0) == 100.0
    assert obs.quantile(vals, 0.5) == 51.0  # nearest rank on 0..99 index grid
    with pytest.raises(ValueError):
        obs.quantile([], 0.5)


def test_prometheus_text_renders_all_kinds():
    reg = obs.MetricRegistry()
    reg.counter("stream.windows").add(3)
    reg.gauge("pipeline.queue_depth").set(2)
    for v in (0.1, 0.2, 0.3):
        reg.histogram("serve.request_latency_s").observe(v)
    text = obs.prometheus_text(reg)
    assert "# TYPE repro_stream_windows counter" in text
    assert "repro_stream_windows 3" in text
    assert "repro_pipeline_queue_depth 2" in text
    assert 'repro_serve_request_latency_s{quantile="0.5"} 0.2' in text


# ---------------------------------------------------------------------------
# JSONL round-trip through the summarizer
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_through_summarizer(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    rec = obs.Recorder((obs.JsonlSink(path),))
    prev = obs.set_recorder(rec)
    try:
        with obs.span("stream.window", window=0):
            with obs.span("hpclust.rounds"):
                obs.event("hpclust.round", round=0, best_obj=10.0,
                          accepted="2/2", quarantined=0)
                obs.event("hpclust.round", round=1, best_obj=8.0,
                          accepted="1/2", quarantined=0)
        obs.inc("stream.windows")
        obs.observe("serve.request_latency_s", 0.25)
    finally:
        obs.set_recorder(prev)
        rec.close()

    spans, events, metrics = obs_cli.load_trace(path)
    assert [s["name"] for s in spans] == ["hpclust.rounds", "stream.window"]
    assert len(events) == 2
    assert metrics["counters"]["stream.windows"] == 1

    out = io.StringIO()
    assert obs_cli.summarize(path, out=out) == 0
    text = out.getvalue()
    assert "stream.window" in text
    assert "hpclust.rounds" in text
    assert "best=10" in text and "best=8" in text
    assert "monotone=True" in text
    assert "serve.request_latency_s" in text

    out = io.StringIO()
    assert obs_cli.prom(path, out=out) == 0
    assert "repro_stream_windows 1" in out.getvalue()


def test_appended_traces_do_not_cross_link(tmp_path):
    """Two CLI invocations append to one file; span ids restart per run but
    the run token keeps the trees separate."""
    path = str(tmp_path / "trace.jsonl")
    for _ in range(2):
        rec = obs.Recorder((obs.JsonlSink(path),))
        with rec.span("root"):
            with rec.span("child"):
                pass
        rec.close()
    spans, _, _ = obs_cli.load_trace(path)
    roots, children = obs_cli.build_tree(spans)
    assert len(roots) == 2
    assert all(len(v) == 1 for v in children.values())


def test_summarizer_exit_codes(tmp_path):
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert obs_cli.summarize(str(empty), out=io.StringIO()) == 1
    bad = tmp_path / "bad.jsonl"
    bad.write_text("{not json\n")
    assert obs_cli.summarize(str(bad), out=io.StringIO()) == 1
    assert obs_cli.main(["summarize", str(empty)]) == 1


def test_jsonl_sink_survives_unserializable_attrs(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    sink = obs.JsonlSink(path)
    sink.write({"type": "event", "name": "e", "ts": 0.0, "run": "r",
                "attrs": {"odd": np.float32(1.5), "obj": object()}})
    sink.close()
    (line,) = open(path).read().splitlines()
    rec = json.loads(line)
    assert rec["attrs"]["odd"] == 1.5
    assert isinstance(rec["attrs"]["obj"], str)


# ---------------------------------------------------------------------------
# integration: traced fit / fit_stream
# ---------------------------------------------------------------------------


def test_traced_fit_stream_emits_expected_spans_and_metrics(recorder):
    from repro.core import HPClust, HPClustConfig

    rec, sink = recorder
    rec.clock = __import__("time").monotonic
    x = np.random.default_rng(0).normal(size=(256, 4)).astype(np.float32)
    est = HPClust(HPClustConfig(k=3, sample_size=64, workers=2, rounds=2))
    res = est.fit_stream([x, x])
    assert res.stats.windows == 2
    names = {s["name"] for s in spans_of(sink)}
    assert {"stream.window", "hpclust.rounds", "sanitize.window"} <= names
    rounds = [r for r in sink.records
              if r["type"] == "event" and r["name"] == "hpclust.round"]
    assert len(rounds) == 4  # 2 windows x 2 rounds
    assert rec.metrics.counter("stream.windows").snapshot() == 2
    assert rec.metrics.counter("stream.rows").snapshot() == 512


def test_fit_unperturbed_when_tracing_disabled():
    """Tracing off: fit produces the identical result (and no records)."""
    from repro.core import HPClust, HPClustConfig

    x = np.random.default_rng(1).normal(size=(256, 4)).astype(np.float32)
    est = HPClust(HPClustConfig(k=3, sample_size=64, workers=2, rounds=2))
    base = est.fit(x)

    sink = obs.ListSink()
    prev = obs.set_recorder(obs.Recorder((sink,)))
    try:
        traced = est.fit(x)
    finally:
        obs.set_recorder(prev)
    assert traced.objective == base.objective
    np.testing.assert_array_equal(traced.centroids, base.centroids)
    assert any(s["name"] == "hpclust.fit" for s in spans_of(sink))


def test_serving_latency_recorded_without_obs():
    """Satellite: Request latency fields are set by the engine clock even
    when no recorder is installed."""
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving import Request, ServeEngine

    prev = obs.set_recorder(None)
    try:
        cfg = get_config("qwen3-0.6b", smoke=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                        max_tokens=2)
                for i in range(2)]
        eng = ServeEngine(cfg, params, slots=2, max_len=64)
        done = eng.run(reqs)
    finally:
        obs.set_recorder(prev)
    assert len(done) == 2
    for r in done:
        assert r.finished_at is not None
        assert r.latency_s is not None and r.latency_s >= 0.0
