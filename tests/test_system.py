"""End-to-end behaviour tests for the paper's system (headline claims)."""
import numpy as np

from repro.core import HPClust, HPClustConfig
from repro.core.baselines import forgy_kmeans, pbk_bdc
from repro.core.hpclust import stream_from_generator
from repro.data import blob_stream, gaussian_blobs


def test_hpclust_solves_mssc_itd_stream():
    """MSSC-ITD e2e: cluster an infinite stream window-by-window; quality on
    held-out data from the same distribution approaches the blob optimum."""
    cfg = HPClustConfig(k=10, sample_size=1024, workers=4, rounds=4,
                        strategy="hybrid")
    hp = HPClust(cfg, seed=0)
    stream = stream_from_generator(blob_stream(16384, n=10, k=10, seed=7), 3)
    res = hp.fit_stream(stream)
    holdout = next(iter(blob_stream(50000, n=10, k=10, seed=7)))
    obj = hp.objective(holdout, res.centroids)
    base = forgy_kmeans(holdout, 10, seed=0)
    assert obj <= base.objective * 1.10, (obj, base.objective)


def test_paper_ordering_hpclust_vs_baselines(blobs):
    """Paper Tables 5/6 qualitative ordering on well-separated blobs:
    HPClust-hybrid <= {PBK-BDC, Forgy} in objective."""
    cfg = HPClustConfig(k=5, sample_size=512, workers=4, rounds=8,
                        strategy="hybrid")
    hp = HPClust(cfg, seed=1)
    res = hp.fit(blobs)
    hp_obj = hp.objective(blobs, res.centroids)
    fg = forgy_kmeans(blobs, 5, seed=1).objective
    pb = pbk_bdc(blobs, 5, segment_size=1000, seed=1).objective
    assert hp_obj <= fg * 1.05
    assert hp_obj <= pb * 1.05


def test_noise_robustness():
    """Paper SS7.1: iterative small-sample processing is robust to noise."""
    x, centers = gaussian_blobs(20000, n=10, k=10, noise_points=1000,
                                sigma_max=2.0, seed=3)
    cfg = HPClustConfig(k=10, sample_size=1024, workers=4, rounds=6,
                        strategy="competitive")
    hp = HPClust(cfg, seed=0)
    res = hp.fit(x)
    # every true center has a found centroid nearby (within 3 units)
    d = np.sqrt(((centers[:, None, :] - res.centroids[None]) ** 2).sum(-1))
    assert (d.min(axis=1) < 3.0).mean() >= 0.8


def test_more_workers_do_not_hurt(blobs):
    """Paper SS5.2: parallelism improves accuracy (monotone in expectation;
    we assert no catastrophic regression on a fixed seed)."""
    objs = {}
    for w in (1, 4):
        cfg = HPClustConfig(k=5, sample_size=384, workers=w, rounds=6,
                            strategy="competitive")
        hp = HPClust(cfg, seed=2)
        res = hp.fit(blobs)
        objs[w] = hp.objective(blobs, res.centroids)
    assert objs[4] <= objs[1] * 1.2, objs
