"""Elastic sharded engine: checkpoint/resume, degraded-mesh recovery, and
collective-failure chaos (ISSUE 9 acceptance tests).

In-process tests cover the host-side pieces (rank rule, checkpointer,
injectors); everything that needs real collectives runs in a subprocess
with 8 forced CPU devices (XLA_FLAGS must precede the jax import).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}

# ---------------------------------------------------------------------------
# in-process units
# ---------------------------------------------------------------------------


def _host_state(w=6, k=3, d=4, seed=0):
    import jax

    from repro.core.sharded import ShardedState

    rng = np.random.default_rng(seed)
    return ShardedState(
        centroids=rng.normal(size=(w, k, d)).astype(np.float32),
        best_obj=np.arange(w, dtype=np.float32),
        degenerate=np.zeros((w, k), np.bool_),
        key=np.asarray(jax.random.split(jax.random.PRNGKey(seed), w)),
        alive=np.ones((w,), np.bool_),
        rounds_done=np.int32(8),
    )


def test_redistribute_rank_rule_shrink():
    from repro.resilience.sharded_ckpt import redistribute_state

    st = _host_state(w=6)
    # Scrambled objectives; one NaN and one dead group must rank last.
    st = st._replace(
        best_obj=np.array([5.0, 1.0, 3.0, np.nan, 2.0, 4.0], np.float32),
        alive=np.array([1, 1, 1, 1, 0, 1], np.bool_),
    )
    hist = np.tile(st.best_obj, (2, 1)).astype(np.float32)
    st2, hist2 = redistribute_state(st, hist, 3)
    # Ranked best of the finite+alive incumbents: 1.0, 3.0, 4.0.
    assert np.array_equal(st2.best_obj, np.array([1.0, 3.0, 4.0], np.float32))
    # Whole rows (centroids, keys, liveness) follow their incumbent.
    assert np.array_equal(st2.centroids, st.centroids[[1, 2, 5]])
    assert np.array_equal(st2.key, st.key[[1, 2, 5]])
    assert st2.alive.all()
    # History columns follow too.
    assert np.array_equal(hist2, hist[:, [1, 2, 5]])
    assert int(st2.rounds_done) == 8


def test_redistribute_rank_rule_grow_forks_keys():
    from repro.resilience.sharded_ckpt import redistribute_state

    st = _host_state(w=4)
    hist = np.zeros((0, 4), np.float32)
    st2, hist2 = redistribute_state(st, hist, 6)
    # First 4 slots: the ranked originals; clones cycle the ranking.
    assert np.array_equal(st2.best_obj, np.array([0, 1, 2, 3, 0, 1],
                                                 np.float32))
    assert np.array_equal(st2.centroids[4], st.centroids[0])
    # Clones explore distinct PRNG streams: forked, not copied, keys.
    assert not np.array_equal(st2.key[4], st2.key[0])
    assert not np.array_equal(st2.key[5], st2.key[1])
    assert hist2.shape == (0, 6)


def test_redistribute_rejects_bad_worker_count():
    from repro.resilience.sharded_ckpt import redistribute_state

    with pytest.raises(ValueError):
        redistribute_state(_host_state(), np.zeros((0, 6), np.float32), 0)


def test_sharded_checkpointer_roundtrip(tmp_path):
    from repro.resilience.sharded_ckpt import ShardedStreamCheckpointer

    ck = ShardedStreamCheckpointer(tmp_path)
    assert ck.latest() is None
    assert ck.restore() is None
    st = _host_state(w=4)
    hist = np.arange(8, dtype=np.float32).reshape(2, 4)
    ck.save(2, st, hist)
    ck.save(3, st._replace(best_obj=st.best_obj + 1.0), hist)
    assert ck.latest() == 3
    snap = ck.restore(step=2)
    assert snap.windows_done == 2
    for got, want in zip(snap.state, st):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(snap.history, hist)


def test_drop_device_midstream_is_exact_and_one_shot():
    from repro.launch.elastic import DeviceLostError
    from repro.resilience.chaos import drop_device_midstream

    factory = drop_device_midstream(at_call=1, lost_devices=(6, 7))
    runner = factory(lambda x: x + 1)
    assert runner(1) == 2  # call 0 passes
    with pytest.raises(DeviceLostError) as ei:
        runner(1)  # call 1 fires
    assert ei.value.lost_devices == (6, 7)
    # One-shot: the retry (and a re-wrapped recompiled runner, which shares
    # the factory's global call counter) proceeds.
    runner2 = factory(lambda x: x + 10)
    assert runner2(1) == 11


def test_is_device_loss_triage():
    from repro.launch.elastic import DeviceLostError, is_device_loss

    assert is_device_loss(DeviceLostError("boom", (0,)))
    assert not is_device_loss(ValueError("bad shape"))

    XlaRuntimeError = type("XlaRuntimeError", (RuntimeError,), {})
    assert is_device_loss(XlaRuntimeError("NCCL communicator shut down"))
    assert is_device_loss(XlaRuntimeError("DEVICE_LOST: peer down"))
    assert not is_device_loss(XlaRuntimeError("INVALID_ARGUMENT: rank"))


def test_poison_worker_group_modes():
    from repro.resilience.chaos import poison_worker_group

    st = _host_state(w=4)
    p = poison_worker_group(st, [1], mode="neginf_obj")
    assert np.asarray(p.best_obj)[1] == -np.inf
    p = poison_worker_group(st, [0, 2], mode="nan_centroids")
    assert np.isnan(np.asarray(p.centroids)[[0, 2]]).all()
    assert np.isfinite(np.asarray(p.centroids)[1]).all()
    # Keys, liveness, and the round counter ride through untouched.
    assert np.array_equal(np.asarray(p.key), st.key)
    assert int(p.rounds_done) == int(st.rounds_done)
    with pytest.raises(ValueError):
        poison_worker_group(st, [0], mode="meteor")


def test_desync_pod_slices_pod_major():
    from repro.resilience.chaos import desync_pod

    st = _host_state(w=6)
    d = desync_pod(st, 2, pods=3, mode="stale")
    assert np.isinf(np.asarray(d.best_obj)[4:]).all()
    assert np.asarray(d.degenerate)[4:].all()
    assert np.array_equal(np.asarray(d.best_obj)[:4], st.best_obj[:4])
    with pytest.raises(ValueError):
        desync_pod(st, 0, pods=4)  # 6 % 4 != 0


# ---------------------------------------------------------------------------
# 8-device subprocess acceptance tests
# ---------------------------------------------------------------------------

PROLOGUE = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import io, json
import numpy as np
import jax


def windows(n, m=2000, d=6, k=4, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-8, 8, size=(k, d))
    for _ in range(n):
        x = np.concatenate(
            [c + rng.normal(scale=0.5, size=(m // k, d)) for c in centers]
        ).astype(np.float32)
        rng.shuffle(x)
        yield x


KW = dict(k=4, sample_size=64, rounds_per_window=4, strategy="hybrid",
          seed=0, ckpt_every=1, kmeans_iters=8)
"""

DROP_SCRIPT = PROLOGUE + r"""
from repro import obs
from repro.launch.elastic import run_elastic_sharded
from repro.obs.cli import summarize
from repro.resilience.chaos import drop_device_midstream
from repro.resilience.sharded_ckpt import ShardedStreamCheckpointer

ckpt_dir, trace = sys.argv[1], sys.argv[2]
obs.configure(jsonl=trace)
res = run_elastic_sharded(
    windows(4), checkpoint_dir=ckpt_dir, mesh_shape=(4, 2),
    runner_wrapper=drop_device_midstream(at_call=2,
                                         lost_devices=(4, 5, 6, 7)),
    **KW,
)
obs.shutdown()
snap2 = ShardedStreamCheckpointer(ckpt_dir).restore(step=2)
buf = io.StringIO()
summarize(trace, out=buf)
print(json.dumps({
    "objective": res.objective,
    "best_at_2": float(np.min(np.asarray(snap2.state.best_obj))),
    "recoveries": res.recoveries,
    "workers": res.workers,
    "windows": res.windows_done,
    "monotone": bool((np.diff(res.history, axis=0) <= 1e-3).all()),
    "banner": "DEGRADED MESH" in buf.getvalue(),
}))
"""

RESUME_SCRIPT = PROLOGUE + r"""
from repro.launch.elastic import run_elastic_sharded
from repro.resilience.chaos import ChaosError, crash_stream

dir_a, dir_b = sys.argv[1], sys.argv[2]
resA = run_elastic_sharded(windows(4), checkpoint_dir=dir_a,
                           mesh_shape=(4, 2), **KW)
crashed = False
try:
    run_elastic_sharded(crash_stream(windows(4), at_window=2),
                        checkpoint_dir=dir_b, mesh_shape=(4, 2), **KW)
except ChaosError:
    crashed = True
resB = run_elastic_sharded(windows(4), checkpoint_dir=dir_b, resume=True,
                           mesh_shape=(4, 2), **KW)
print(json.dumps({
    "crashed": crashed,
    "resumed_at": resB.resumed_at,
    "state_equal": bool(
        np.array_equal(np.asarray(resA.state.centroids),
                       np.asarray(resB.state.centroids))
        and np.array_equal(np.asarray(resA.state.best_obj),
                           np.asarray(resB.state.best_obj))
        and np.array_equal(np.asarray(resA.state.key),
                           np.asarray(resB.state.key))
        and int(resA.state.rounds_done) == int(resB.state.rounds_done)
    ),
    "history_equal": bool(np.array_equal(resA.history, resB.history)),
}))
"""

SHRINK_SCRIPT = PROLOGUE + r"""
from repro.launch.elastic import run_elastic_sharded
from repro.resilience.sharded_ckpt import (
    ShardedStreamCheckpointer,
    redistribute_state,
)

ckpt_dir = sys.argv[1]
run_elastic_sharded(windows(2), checkpoint_dir=ckpt_dir,
                    mesh_shape=(8, 1), **KW)
snap = ShardedStreamCheckpointer(ckpt_dir).restore()
o8 = np.sort(np.asarray(snap.state.best_obj))
st2, hist2 = redistribute_state(snap.state, snap.history, 2)
res2 = run_elastic_sharded(windows(3), checkpoint_dir=ckpt_dir, resume=True,
                           mesh_shape=(2, 2), **KW)
print(json.dumps({
    "orig_workers": int(o8.shape[0]),
    "ranked": bool(np.array_equal(np.asarray(st2.best_obj), o8[:2])),
    "hist_cols": int(hist2.shape[1]),
    "workers": res2.workers,
    "resumed_at": res2.resumed_at,
    "no_regress": bool(res2.objective <= float(o8[0]) + 1e-4),
    "monotone": bool((np.diff(res2.history, axis=0) <= 1e-3).all()),
}))
"""

LIVENESS_SCRIPT = PROLOGUE + r"""
import jax.numpy as jnp
from repro.core import sharded
from repro.core.strategies import HPClustConfig
from repro.resilience.chaos import poison_worker_group

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = HPClustConfig(k=4, sample_size=64, workers=4, rounds=4,
                    strategy="hybrid", fixed_schedule=True, kmeans_iters=8,
                    groups=2)
x = next(windows(1))
res = jnp.asarray(np.broadcast_to(x, (4,) + x.shape))
fn, in_sh, out_sh = sharded.build_sharded_runner(mesh, cfg)
jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
st, _ = jfn(sharded.init_sharded_state(cfg, x.shape[1], seed=0), res)
st = poison_worker_group(st, [1], mode="neginf_obj")
st = sharded.mark_dead(st, [2])
frozen_c = np.asarray(st.centroids[2])
frozen_o = float(np.asarray(st.best_obj[2]))
st2, objs = jfn(st, res)
best_c, best_o = sharded.best_of(st2)
print(json.dumps({
    "frozen": bool(
        np.array_equal(np.asarray(st2.centroids[2]), frozen_c)
        and float(np.asarray(st2.best_obj[2])) == frozen_o
    ),
    "poison_recovered": bool(np.isfinite(float(np.asarray(st2.best_obj[1])))),
    "objs_finite": bool(np.isfinite(np.asarray(objs)).all()),
    "best_finite": bool(np.isfinite(float(best_o))),
}))
"""

DESYNC_SCRIPT = PROLOGUE + r"""
import jax.numpy as jnp
from repro.core import sharded
from repro.core.strategies import HPClustConfig
from repro.resilience.chaos import desync_pod

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = HPClustConfig(k=4, sample_size=32, workers=4, rounds=6,
                    strategy="hybrid2", fixed_schedule=True, kmeans_iters=8,
                    groups=2, sync_every=2)
x = next(windows(1, m=1000))
res = jnp.asarray(np.broadcast_to(x, (4,) + x.shape))
fn, in_sh, out_sh = sharded.build_sharded_runner(mesh, cfg, pod_axis="pod")
jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
st, _ = jfn(sharded.init_sharded_state(cfg, x.shape[1], seed=0), res)
pre_best = float(np.min(np.asarray(st.best_obj)))
st_d = desync_pod(st, 1, pods=2, mode="stale")
st2, _ = jfn(st_d, res)
post = np.asarray(st2.best_obj)
print(json.dumps({
    "desynced_inf": bool(np.isinf(np.asarray(st_d.best_obj)[2:]).all()),
    "recovered": bool(np.isfinite(post).all()),
    "no_regress": bool(float(np.min(post)) <= pre_best + 1e-4),
}))
"""


def _run(script, *argv):
    out = subprocess.run(
        [sys.executable, "-c", script, *map(str, argv)],
        capture_output=True, text=True, env=ENV, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_drop_device_recovers_on_degraded_mesh(tmp_path):
    """ISSUE 9 acceptance: device loss at window 2 -> rebuild (2,2) mesh
    from the 4 survivors, resume from the last checkpoint, and the final
    global best is <= the incumbent best at the drop point."""
    rec = _run(DROP_SCRIPT, tmp_path / "ckpt", tmp_path / "trace.jsonl")
    assert rec["recoveries"] == 1
    assert rec["workers"] == 2  # 4 surviving devices -> (2, 2) mesh
    assert rec["windows"] == 4  # no window is lost, only retried
    assert rec["objective"] <= rec["best_at_2"] + 1e-4
    assert rec["monotone"]
    assert rec["banner"]  # summarize prints the degraded-mesh banner


def test_same_mesh_crash_resume_is_bit_for_bit(tmp_path):
    rec = _run(RESUME_SCRIPT, tmp_path / "a", tmp_path / "b")
    assert rec["crashed"]
    assert rec["resumed_at"] == 2
    assert rec["state_equal"]
    assert rec["history_equal"]


def test_mesh_shrink_restore_keeps_ranked_best(tmp_path):
    rec = _run(SHRINK_SCRIPT, tmp_path / "ckpt")
    assert rec["orig_workers"] == 8
    assert rec["ranked"]
    assert rec["hist_cols"] == 2
    assert rec["workers"] == 2
    assert rec["resumed_at"] == 2
    assert rec["no_regress"]
    assert rec["monotone"]


def test_liveness_mask_freezes_dead_group(tmp_path):
    rec = _run(LIVENESS_SCRIPT)
    assert rec["frozen"]
    assert rec["poison_recovered"]
    assert rec["objs_finite"]
    assert rec["best_finite"]


def test_desync_pod_repaired_by_cross_pod_sync(tmp_path):
    rec = _run(DESYNC_SCRIPT)
    assert rec["desynced_inf"]
    assert rec["recovered"]
    assert rec["no_regress"]
