"""Distributed (shard_map) HPClust + small-mesh dry-run checks.

These spawn subprocesses where needed to control the forced device count;
in-process tests use a (1,1) mesh over the single CPU device.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core.strategies import HPClustConfig
from repro.core import sharded

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = HPClustConfig(k=5, sample_size=64, workers=4, rounds=6,
                    strategy="%s", fixed_schedule=True, kmeans_iters=16,
                    groups=2)
rng = np.random.default_rng(0)
centers = rng.uniform(-10, 10, size=(5, 8))
x = np.concatenate([c + rng.normal(scale=0.5, size=(500, 8)) for c in centers]).astype(np.float32)
rng.shuffle(x)
res = np.broadcast_to(x, (4, 2500, 8)).copy()
fn, in_sh, out_sh = sharded.build_sharded_runner(mesh, cfg)
state = sharded.init_sharded_state(cfg, 8, seed=0)
jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
st, objs = jfn(state, jnp.asarray(res))
objs = np.asarray(objs)
print(json.dumps({
    "monotone": bool((np.diff(objs, axis=0) <= 1e-3).all()),
    "best": float(np.min(np.asarray(st.best_obj))),
    "finite": bool(np.isfinite(objs).all()),
    "rounds_done": int(np.asarray(st.rounds_done)),
}))
"""


@pytest.mark.parametrize("strategy", ["competitive", "cooperative", "hybrid"])
def test_sharded_runner_on_8_devices(strategy):
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT % strategy],
        capture_output=True, text=True, env=ENV, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["finite"]
    assert rec["monotone"]
    assert rec["rounds_done"] == 6
    # blobs: optimal sample objective ~ 64 points * d * sigma^2 = 128
    assert rec["best"] < 500.0, rec


MULTIPOD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core.strategies import HPClustConfig
from repro.core import sharded

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = HPClustConfig(k=4, sample_size=32, workers=4, rounds=6,
                    strategy="hybrid2", fixed_schedule=True, kmeans_iters=8,
                    groups=2, sync_every=2)
rng = np.random.default_rng(0)
x = rng.normal(size=(1000, 6)).astype(np.float32)
res = np.broadcast_to(x, (4, 1000, 6)).copy()
fn, in_sh, out_sh = sharded.build_sharded_runner(mesh, cfg, pod_axis="pod")
state = sharded.init_sharded_state(cfg, 6, seed=0)
jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
st, objs = jfn(state, jnp.asarray(res))
print(json.dumps({"finite": bool(np.isfinite(np.asarray(objs)).all()),
                  "monotone": bool((np.diff(np.asarray(objs), axis=0) <= 1e-3).all())}))
"""


def test_hybrid2_multipod_mesh():
    out = subprocess.run(
        [sys.executable, "-c", MULTIPOD_SCRIPT],
        capture_output=True, text=True, env=ENV, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["finite"] and rec["monotone"]


def test_dryrun_cell_compiles_on_host_mesh():
    """Full-size qwen3-0.6b train cell lowers+compiles on a (1,1) mesh —
    the in-process analogue of the 512-device dry-run."""
    import jax

    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((1, 1))
    cfg, fn, args, _ = build_cell("qwen3-0.6b", "train_4k", mesh)
    with mesh:
        compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns per-program dicts
        ca = ca[0] if ca else {}
    assert ca.get("flops", 0) > 1e12


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag.1 = bf16[64]{0} all-gather(bf16[32]{0} %y), dimensions={0}
  %noise = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 32 * 2
    assert "add" not in out
