"""Core clustering invariants: Lloyd, K-means++, strategies, streams."""
import dataclasses

try:  # property tests degrade to fixed-seed parametrize without hypothesis
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HPClust, HPClustConfig, best_of
from repro.core import kmeans as km
from repro.core import kmeanspp as kpp
from repro.core import strategies as strat
from repro.core.baselines import forgy_kmeans, minibatch_kmeans, pbk_bdc
from repro.core.hpclust import stream_from_generator
from repro.data import blob_stream
from repro.kernels import ref


# ---------------------------------------------------------------------------
# Lloyd
# ---------------------------------------------------------------------------


def test_lloyd_objective_monotone(blobs):
    x = jnp.asarray(blobs)
    c = x[:7]
    objs = []
    for _ in range(12):
        c, obj, _, _ = km.lloyd_iteration(x, c)
        objs.append(float(obj))
    assert all(a >= b - 1e-3 for a, b in zip(objs, objs[1:])), objs


def test_lloyd_centroid_is_mean(blobs):
    x = jnp.asarray(blobs[:500])
    c0 = x[:4]
    idx, _ = ref.assign_ref(x, c0)
    new_c, _, counts, _ = km.lloyd_iteration(x, c0)
    for j in range(4):
        mask = np.asarray(idx) == j
        if mask.any():
            np.testing.assert_allclose(
                np.asarray(new_c)[j], np.asarray(x)[mask].mean(0),
                rtol=1e-4, atol=1e-4,
            )


def test_kmeans_converges_and_flags_iterations(blobs):
    x = jnp.asarray(blobs)
    res = km.kmeans(x, x[:5], max_iters=300, tol=1e-4)
    assert int(res.iterations) > 1
    assert np.isfinite(float(res.objective))
    res2 = km.kmeans_fixed(x, x[:5], iters=32)
    np.testing.assert_allclose(
        float(res.objective), float(res2.objective), rtol=0.05
    )


def test_empty_cluster_keeps_old_centroid():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(50, 3)).astype(np.float32))
    far = jnp.asarray(np.full((1, 3), 1e3, np.float32))
    c = jnp.concatenate([x[:2], far])
    new_c, _, counts, degenerate = km.lloyd_iteration(x, c)
    assert bool(degenerate[2])
    np.testing.assert_allclose(np.asarray(new_c)[2], np.asarray(far)[0])


# ---------------------------------------------------------------------------
# K-means++
# ---------------------------------------------------------------------------


def test_kmeanspp_centers_are_data_points(blobs):
    x = jnp.asarray(blobs[:512])
    c = kpp.kmeanspp(jax.random.PRNGKey(0), x, 6)
    xs = np.asarray(x)
    for row in np.asarray(c):
        d = ((xs - row[None]) ** 2).sum(1).min()
        assert d < 1e-8


def test_reseed_only_touches_masked_rows(blobs):
    x = jnp.asarray(blobs[:256])
    c0 = jnp.asarray(np.random.default_rng(1).normal(size=(5, 8)).astype(np.float32))
    mask = jnp.asarray([False, True, False, False, True])
    c1 = kpp.reseed_degenerate(jax.random.PRNGKey(1), x, c0, mask)
    keep = ~np.asarray(mask)
    np.testing.assert_allclose(np.asarray(c1)[keep], np.asarray(c0)[keep])
    assert not np.allclose(np.asarray(c1)[~keep], np.asarray(c0)[~keep])


def test_kmeanspp_handles_duplicate_points():
    x = jnp.asarray(np.ones((32, 4), np.float32))
    c = kpp.kmeanspp(jax.random.PRNGKey(0), x, 3)
    assert np.isfinite(np.asarray(c)).all()


def _check_kmeanspp_spread(k, seed):
    """D^2 seeding potential should not be wildly worse than uniform's."""
    r = np.random.default_rng(seed)
    centers = r.uniform(-20, 20, (k, 4))
    x = np.concatenate([c + r.normal(scale=0.1, size=(50, 4)) for c in centers])
    xj = jnp.asarray(x.astype(np.float32))
    cpp = kpp.kmeanspp(jax.random.PRNGKey(seed), xj, k)
    uni = xj[r.integers(0, len(x), k)]
    pot_pp = float(ref.mssc_objective_ref(xj, cpp))
    pot_uni = float(ref.mssc_objective_ref(xj, uni))
    assert pot_pp <= pot_uni * 2.0 + 1e-3


if hypothesis is not None:

    @hypothesis.settings(deadline=None, max_examples=10)
    @hypothesis.given(k=st.integers(2, 8), seed=st.integers(0, 1000))
    def test_kmeanspp_spreads_better_than_uniform(k, seed):
        _check_kmeanspp_spread(k, seed)

else:

    @pytest.mark.parametrize("k,seed", [(2, 0), (4, 77), (8, 1000)])
    def test_kmeanspp_spreads_better_than_uniform(k, seed):
        _check_kmeanspp_spread(k, seed)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["competitive", "cooperative", "hybrid", "hybrid2"])
def test_incumbent_monotone_per_worker(blobs, strategy):
    """Keep-the-best: per-worker incumbent objective never increases (the
    paper's central monotonicity property)."""
    cfg = HPClustConfig(k=5, sample_size=256, workers=4, rounds=6,
                        strategy=strategy, groups=2)
    _, metrics = jax.jit(strat.run_hpclust, static_argnames=("cfg",))(
        jax.random.PRNGKey(0), jnp.asarray(blobs), cfg=cfg
    )
    hist = np.asarray(metrics.best_obj)  # (rounds, W)
    assert (np.diff(hist, axis=0) <= 1e-3).all()


def test_cooperative_propagates_best(blobs):
    cfg = HPClustConfig(k=5, sample_size=256, workers=4, rounds=8,
                        strategy="cooperative")
    state, metrics = jax.jit(strat.run_hpclust, static_argnames=("cfg",))(
        jax.random.PRNGKey(0), jnp.asarray(blobs), cfg=cfg
    )
    hist = np.asarray(metrics.best_obj)
    # After enough cooperative rounds workers should agree within noise.
    spread = hist[-1].max() / hist[-1].min()
    assert spread < 1.5, hist[-1]


def test_best_of_selects_argmin(blobs):
    cfg = HPClustConfig(k=5, sample_size=256, workers=4, rounds=4,
                        strategy="competitive")
    state, _ = jax.jit(strat.run_hpclust, static_argnames=("cfg",))(
        jax.random.PRNGKey(0), jnp.asarray(blobs), cfg=cfg
    )
    c, obj = best_of(state)
    assert float(obj) == pytest.approx(float(np.asarray(state.best_obj).min()))


def test_hpclust_beats_forgy_on_blobs(blobs):
    cfg = HPClustConfig(k=5, sample_size=512, workers=4, rounds=8,
                        strategy="hybrid")
    hp = HPClust(cfg, seed=0)
    res = hp.fit(blobs)
    full = hp.objective(blobs, res.centroids)
    fb = forgy_kmeans(blobs, 5, seed=0)
    assert full <= fb.objective * 1.05  # paper: HPClust >= Forgy quality


def test_fit_stream_carries_incumbents():
    cfg = HPClustConfig(k=4, sample_size=256, workers=2, rounds=3,
                        strategy="competitive")
    hp = HPClust(cfg, seed=0)
    stream = stream_from_generator(blob_stream(4096, n=6, k=4, seed=0), 3)
    res = hp.fit_stream(stream)
    hist = res.history  # (3*rounds, W)
    assert hist.shape[0] == 9
    assert (np.diff(hist, axis=0) <= 1e-3).all()  # monotone ACROSS windows


def test_assign_and_objective_batched(blobs):
    cfg = HPClustConfig(k=5, sample_size=128, workers=2, rounds=2)
    hp = HPClust(cfg, seed=0)
    res = hp.fit(blobs)
    y = hp.assign(blobs, res.centroids, batch=500)
    assert y.shape == (len(blobs),)
    assert y.max() < 5
    o1 = hp.objective(blobs, res.centroids, batch=500)
    o2 = hp.objective(blobs, res.centroids, batch=len(blobs))
    np.testing.assert_allclose(o1, o2, rtol=1e-5)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


def test_baselines_sane(blobs):
    f = forgy_kmeans(blobs, 5, seed=0)
    p = pbk_bdc(blobs, 5, segment_size=1000, seed=0)
    m = minibatch_kmeans(blobs, 5, steps=30, seed=0)
    for r in (f, p, m):
        assert np.isfinite(r.objective)
        assert r.centroids.shape == (5, 8)
