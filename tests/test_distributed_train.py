"""Multi-device SPMD integration: sharded training + sharded clustering
actually RUN (not just compile) on 8 forced host devices, and checkpoints
round-trip across device counts (elastic restart)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}

TRAIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.models import model as M

# small real mesh: 4-way DP x 2-way TP (axis_types defaults to Auto, and
# the kwarg does not exist on older jax)
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("qwen3-0.6b", smoke=True)

p_shard = shd.param_shardings(cfg, mesh)
step = S.make_train_step(cfg, grad_accum=1)
opt = step.optimizer

params_host = M.init_params(cfg, jax.random.PRNGKey(0))
with mesh:
    params = {k: jax.device_put(v, p_shard[k]) for k, v in params_host.items()}
    opt_state = opt.init(params)
    b_shard = NamedSharding(mesh, P(("data",), None))
    M.set_activation_spec(P(("data",), None, None))
    jstep = jax.jit(step, donate_argnums=(0, 1))
    rng = np.random.default_rng(0)
    losses = []
    for i in range(6):
        batch = {"tokens": jax.device_put(
            jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32))), b_shard)}
        params, opt_state, m = jstep(params, opt_state, batch)
        losses.append(float(m["loss"]))

# params remain sharded as requested
sharded_ok = all(
    params[k].sharding == p_shard[k] for k in list(params)[:10]
)
print(json.dumps({
    "losses": losses,
    "finite": all(np.isfinite(losses)),
    "decreasing": losses[-1] < losses[0] + 0.5,
    "sharded_ok": bool(sharded_ok),
    "n_devices": len(jax.devices()),
}))
"""


def test_sharded_training_runs_on_8_devices():
    out = subprocess.run(
        [sys.executable, "-c", TRAIN_SCRIPT],
        capture_output=True, text=True, env=ENV, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 8
    assert rec["finite"], rec
    assert rec["decreasing"], rec
    assert rec["sharded_ok"]


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import json, sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

mgr = CheckpointManager("%s")
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
if "%s" == "save":
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    mgr.save(3, {"w": jax.device_put(tree["w"], sh)})
    print(json.dumps({"saved": True}))
else:
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, out = mgr.restore(tree, shardings=sh)
    print(json.dumps({
        "step": step,
        "match": bool(np.allclose(np.asarray(out["w"]), np.asarray(tree["w"]))),
        "devices": len(jax.devices()),
    }))
"""


def test_elastic_restart_across_device_counts(tmp_path):
    """Save sharded over 8 devices, restore sharded over 2 — the elastic
    restart path end to end."""
    d = str(tmp_path / "ck")
    r1 = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (8, d, "save")],
        capture_output=True, text=True, env=ENV, timeout=600,
    )
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (2, d, "load")],
        capture_output=True, text=True, env=ENV, timeout=600,
    )
    assert r2.returncode == 0, r2.stderr[-2000:]
    rec = json.loads(r2.stdout.strip().splitlines()[-1])
    assert rec == {"step": 3, "match": True, "devices": 2}
