"""Known-bad pallas_call sites for the PK check family.

NEVER imported or executed — consumed as text by tests/test_analysis.py.
``# F:<CODE>`` tags mark the exact line each finding must anchor to.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 2048


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _kernel3(x_ref, a_ref, b_ref):
    a_ref[...] = x_ref[...]
    b_ref[...] = x_ref[...]


def bad_grid_arity(x):
    """index_map takes 3 program ids but the grid has 2 axes."""
    return pl.pallas_call(
        _kernel,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((8, 128), lambda i, j, k: (i, j)),  # F:PK001
        ],
        out_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((32, 512), jnp.float32)],
    )(x)


def bad_alignment(x):
    """(100, 257) is aligned to neither sublanes nor lanes."""
    return pl.pallas_call(
        _kernel,
        grid=(4,),
        in_specs=[
            pl.BlockSpec((100, 257), lambda i: (i, 0)),  # F:PK002
        ],
        out_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((32, 128), jnp.float32)],
    )(x)


def bad_kernel_arity(x):
    """2 in + 1 out + 1 scratch = 4 refs, but `_kernel` only takes 2."""
    return pl.pallas_call(  # F:PK003
        _kernel,
        grid=(4,),
        in_specs=[
            pl.BlockSpec((8, 128), lambda i: (i, 0)),
            pl.BlockSpec((8, 128), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((32, 128), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
    )(x, x)


def over_budget(x):
    """2x(32 MiB in) + 2x(32 MiB out) + 4 MiB scratch >> 16 MiB VMEM."""
    return pl.pallas_call(  # F:PK004
        _kernel3,
        grid=(2,),
        in_specs=[pl.BlockSpec((BIG, BIG), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BIG, BIG), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((4096, 2048), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1024, 1024), jnp.float32)],
    )(x)


def mismatched_outputs(x):
    """Two out_specs but only one out_shape entry."""
    return pl.pallas_call(  # F:PK005
        _kernel3,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((8, 128), lambda i: (i, 0)),
            pl.BlockSpec((8, 128), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((8, 128), jnp.float32)],
    )(x)
