"""Known-bad jit usage for the JH check family.

NEVER imported or executed — consumed as text by tests/test_analysis.py.
``# F:<CODE>`` tags mark the exact line each finding must anchor to.
"""
import functools

import jax
import numpy as np


def scale(x, n):
    return x * n


_jit_wrong_name = jax.jit(scale, static_argnames=("m",))  # F:JH001


def axpy(a, b):
    return a + b


_jit_bad_donate = jax.jit(axpy, donate_argnums=(5,))  # F:JH002


class Runner:
    def step(self, x):
        fn = jax.jit(lambda y: y * 2)  # F:JH003
        return fn(x)


@functools.partial(jax.jit, static_argnames=("opts",))
def with_unhashable(x, *, opts=[1, 2]):  # F:JH004
    return x if opts else -x


@jax.jit
def leaky(x):
    noise = np.random.normal(size=(4,))  # F:JH005
    bias = np.asarray([1.0, 2.0, 3.0, 4.0])  # F:JH005
    return x + noise + bias
