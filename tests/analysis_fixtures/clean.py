"""A well-formed Pallas + jit module: every check must stay silent here.

NEVER imported or executed — consumed as text by tests/test_analysis.py.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 256
BLOCK_K = 128


def _matmul_kernel(x_ref, c_ref, o_ref, acc_ref, *, nd: int):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(di == nd - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.lru_cache(maxsize=None)
def _cached_step(nd: int):
    # Memoized jit factory: constructed once per key, not per call — the
    # sanctioned JH003 alternative; must stay silent.
    return jax.jit(functools.partial(_matmul_kernel, nd=nd))


@functools.partial(jax.jit, static_argnames=("block_s", "block_k", "block_d"))
def matmul(
    x: jax.Array,
    c: jax.Array,
    *,
    block_s: int = BLOCK_S,
    block_k: int = BLOCK_K,
    block_d: int = 256,
) -> jax.Array:
    s, d = x.shape
    k = c.shape[0]
    bs, bk, bd = min(block_s, s), min(block_k, k), min(block_d, d)
    ns, nk, nd = s // bs, k // bk, d // bd
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nd=nd),
        grid=(ns, nk, nd),
        in_specs=[
            pl.BlockSpec((bs, bd), lambda si, ki, di: (si, di)),
            pl.BlockSpec((bk, bd), lambda si, ki, di: (ki, di)),
        ],
        out_specs=[pl.BlockSpec((bs, bk), lambda si, ki, di: (si, ki))],
        out_shape=[jax.ShapeDtypeStruct((s, k), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bs, bk), jnp.float32)],
    )(x.astype(jnp.float32), c.astype(jnp.float32))[0]
