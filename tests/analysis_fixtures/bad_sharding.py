"""Known-bad sharding specs for the SH check family.

NEVER imported or executed — consumed as text by tests/test_analysis.py.
``# F:<CODE>`` tags mark the exact line each finding must anchor to.
"""
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"))
pod_mesh = Mesh(jax.devices(), axis_names=("pod", "data"))

# Typo'd axis: silently replicates instead of sharding over 'data'.
bad = NamedSharding(mesh, P("dat", None))  # F:SH001

# One-hop resolution: the spec variable's P(...) is still checked
# (the finding anchors at the bad literal inside the P call).
spec = P(("data", "modle"), None)  # F:SH001
also_bad = NamedSharding(mesh, spec)

# Axis from a *different* mesh than the one this call consumes.
crossed = NamedSharding(pod_mesh, P("model"))  # F:SH001

good = NamedSharding(mesh, P("data", "model"))
replicated = NamedSharding(mesh, P(None))


def body(x):
    return x


mapped = shard_map(
    body,
    mesh=mesh,
    in_specs=(P("data", "modell"),),  # F:SH001
    out_specs=P("data"),
)


def unknown_mesh(m):
    # Mesh is a parameter — not resolvable, so never flagged.
    return NamedSharding(m, P("definitely_not_an_axis"))
