"""Known-bad dtype usage for the DT check family.

NEVER imported or executed — consumed as text by tests/test_analysis.py.
``# F:<CODE>`` tags mark the exact line each finding must anchor to.
"""
import jax
import jax.numpy as jnp


def promote(x):
    return x.astype(jnp.float64)  # F:DT001


def make_buf(n):
    return jnp.zeros((n,), dtype="float64")  # F:DT001


def _bad_kernel(x_ref, c_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(  # F:DT002
        x_ref[...],
        c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
    )
