"""Known-bad library-side printing for the OB check family.

NEVER imported or executed — consumed as text by tests/test_analysis.py.
``# F:<CODE>`` tags mark the exact line each finding must anchor to.
"""
import sys


def hot_loop(windows):
    for i, w in enumerate(windows):
        print(f"window {i}: rows={len(w)}")  # F:OB001
        yield w


def report(stats):
    print("done", stats)  # F:OB001
    # Deliberate diagnostics to stderr stay allowed:
    print("warning: sanitized rows", file=sys.stderr)
