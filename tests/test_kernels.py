"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
try:  # property tests degrade to fixed-seed parametrize without hypothesis
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (8, 3, 4),       # tiny, everything padded
    (100, 7, 33),    # ragged in all dims
    (256, 128, 256), # exactly one tile
    (300, 130, 300), # just over one tile
    (1024, 16, 768), # tall: CORD-19-like dims
]
DTYPES = [np.float32, np.bfloat16] if hasattr(np, "bfloat16") else [np.float32]


def _mk(s, k, d, dtype=np.float32, seed=0):
    r = np.random.default_rng(seed)
    x = r.normal(size=(s, d)).astype(np.float32)
    c = r.normal(size=(k, d)).astype(np.float32)
    return jnp.asarray(x, dtype), jnp.asarray(c, dtype)


@pytest.mark.parametrize("s,k,d", SHAPES)
def test_assign_matches_ref(s, k, d):
    x, c = _mk(s, k, d)
    i_ref, d_ref = ref.assign_ref(x, c)
    i_pal, d_pal = ops.assign_clusters(x, c, impl="interpret")
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pal))
    np.testing.assert_allclose(
        np.asarray(d_ref), np.asarray(d_pal), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("s,k,d", SHAPES)
def test_cluster_sums_matches_ref(s, k, d):
    x, c = _mk(s, k, d)
    idx, _ = ref.assign_ref(x, c)
    s_ref, n_ref = ref.cluster_sums_ref(x, idx, k)
    s_pal, n_pal = ops.cluster_sums(x, idx, k, impl="interpret")
    np.testing.assert_array_equal(np.asarray(n_ref), np.asarray(n_pal))
    np.testing.assert_allclose(
        np.asarray(s_ref), np.asarray(s_pal), rtol=1e-4, atol=1e-4
    )


def test_assign_bf16_inputs():
    x, c = _mk(64, 9, 40, dtype=jnp.bfloat16)
    i_ref, _ = ref.assign_ref(x, c)
    i_pal, _ = ops.assign_clusters(x, c, impl="interpret")
    # bf16 rounding can flip genuinely ambiguous rows; demand 99% agreement
    agree = np.mean(np.asarray(i_ref) == np.asarray(i_pal))
    assert agree > 0.99


def _check_assign_is_true_argmin(s, k, d, seed):
    """Property: returned index minimizes the exact distance, and the
    returned distance equals that minimum (within fp tolerance)."""
    r = np.random.default_rng(seed)
    x = r.normal(size=(s, d)).astype(np.float32)
    c = r.normal(size=(k, d)).astype(np.float32)
    idx, dist = ops.assign_clusters(jnp.asarray(x), jnp.asarray(c), impl="interpret")
    d2 = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    best = d2.min(1)
    np.testing.assert_allclose(np.asarray(dist), best, rtol=1e-3, atol=1e-3)
    chosen = d2[np.arange(s), np.asarray(idx)]
    np.testing.assert_allclose(chosen, best, rtol=1e-3, atol=1e-3)


def _check_cluster_sums_partition(s, k, seed):
    """Property: sums over clusters == total sum; counts sum to s."""
    r = np.random.default_rng(seed)
    x = r.normal(size=(s, 7)).astype(np.float32)
    idx = r.integers(0, k, size=s).astype(np.int32)
    sums, counts = ops.cluster_sums(
        jnp.asarray(x), jnp.asarray(idx), k, impl="interpret"
    )
    np.testing.assert_allclose(
        np.asarray(sums).sum(0), x.sum(0), rtol=1e-4, atol=1e-4
    )
    assert np.asarray(counts).sum() == s


if hypothesis is not None:

    @hypothesis.settings(deadline=None, max_examples=25)
    @hypothesis.given(
        s=st.integers(2, 64), k=st.integers(1, 17), d=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_assign_is_true_argmin(s, k, d, seed):
        _check_assign_is_true_argmin(s, k, d, seed)

    @hypothesis.settings(deadline=None, max_examples=25)
    @hypothesis.given(
        s=st.integers(1, 80), k=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_cluster_sums_partition_property(s, k, seed):
        _check_cluster_sums_partition(s, k, seed)

else:

    @pytest.mark.parametrize(
        "s,k,d,seed", [(2, 1, 1, 0), (33, 17, 48, 5), (64, 9, 7, 1234)]
    )
    def test_assign_is_true_argmin(s, k, d, seed):
        _check_assign_is_true_argmin(s, k, d, seed)

    @pytest.mark.parametrize(
        "s,k,seed", [(1, 1, 0), (80, 9, 42), (17, 3, 999)]
    )
    def test_cluster_sums_partition_property(s, k, seed):
        _check_cluster_sums_partition(s, k, seed)


def test_assign_padding_never_wins():
    """Padded centroid rows (k not tile-aligned) must never be selected."""
    x, c = _mk(64, 5, 16, seed=3)
    idx, _ = ops.assign_clusters(x, c, impl="interpret")
    assert int(np.asarray(idx).max()) < 5


def test_objective_matches():
    x, c = _mk(128, 6, 10)
    o1 = float(ops.mssc_objective(x, c, impl="ref"))
    o2 = float(ops.mssc_objective(x, c, impl="interpret"))
    np.testing.assert_allclose(o1, o2, rtol=1e-5)


@pytest.mark.parametrize("s,k,d", [(64, 5, 16), (300, 17, 96), (256, 128, 256)])
def test_fused_lloyd_pass_matches_two_kernel_path(s, k, d):
    x, c = _mk(s, k, d, seed=7)
    i1, d1 = ref.assign_ref(x, c)
    s1, n1 = ref.cluster_sums_ref(x, i1, k)
    i2, d2, s2, n2 = ops.lloyd_pass(x, c, impl="interpret")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))


def test_fused_lloyd_pass_ref_fallback():
    x, c = _mk(100, 7, 33)
    i, dd, ss, nn = ops.lloyd_pass(x, c, impl="ref")
    i2, _ = ref.assign_ref(x, c)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i2))
