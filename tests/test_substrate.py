"""Substrate: optimizers, checkpoint/restart/elastic, trainer fault
tolerance, compression, data pipeline, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import blob_stream, gaussian_blobs, token_batches
from repro.distributed import compression as comp
from repro.launch import steps as S
from repro.models import model as M
from repro.optim import adafactor, adamw, clip_by_global_norm
from repro.runtime import StepFailure, Trainer, TrainerConfig
from repro.serving import Request, ServeEngine


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("opt_fn", [adamw, adafactor])
def test_optimizer_descends_quadratic(opt_fn):
    opt = opt_fn(0.1)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                               jnp.float32)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.5 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0), "b": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float(jnp.sum(v ** 2)) for v in clipped.values()))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(2000.0), rel=1e-5)


def test_adafactor_state_is_factored():
    opt = adafactor(0.01)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st.slots["w"].row.shape == (64,)
    assert st.slots["w"].col.shape == (32,)
    assert st.slots["b"].full.shape == (32,)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr.save(3, tree)
    step, out = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 5, 9):
        mgr.save(s, tree)
    assert mgr.all_steps() == [5, 9]
    assert mgr.latest_step() == 9


def test_checkpoint_integrity_check(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"a": jnp.zeros((128,))}
    mgr.save(0, tree)
    # corrupt the payload
    p = tmp_path / "step_0000000000" / "leaves.npz"
    raw = bytearray(p.read_bytes())
    raw[-1] ^= 0xFF
    p.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        mgr.restore(tree)


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore onto a (1,1) mesh sharding — the elastic-restart path: the
    checkpoint knows nothing about the writer's mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(0, tree)
    mesh = make_host_mesh((1, 1))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    _, out = mgr.restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    tree = {"a": jnp.ones((1000,))}
    mgr.save(7, tree, block=False)
    mgr.wait()
    assert mgr.latest_step() == 7


# ---------------------------------------------------------------------------
# trainer fault tolerance
# ---------------------------------------------------------------------------


def _tiny_trainer(tmp_path, failure_at=None, steps=12):
    cfg = get_config("qwen3-0.6b", smoke=True)
    step_fn = jax.jit(S.make_train_step(cfg, grad_accum=1))
    opt = step_fn.__wrapped__.optimizer

    def init_state():
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        return params, opt.init(params)

    data = token_batches(cfg.vocab_size, 2, 16, seed=0)
    return Trainer(
        TrainerConfig(total_steps=steps, ckpt_every=4,
                      ckpt_dir=str(tmp_path / "ckpt")),
        step_fn, init_state, data, failure_at=failure_at,
    )


def test_trainer_completes(tmp_path):
    t = _tiny_trainer(tmp_path, steps=6)
    res = t.run()
    assert res["status"] == "done"
    assert res["step"] == 6


def test_trainer_survives_injected_failures(tmp_path):
    """Crash at steps 6 and 10 -> restart from the step-4/8 checkpoints,
    replay the lost steps, finish."""
    t = _tiny_trainer(tmp_path, failure_at={6, 10}, steps=12)
    res = t.run()
    assert res["status"] == "done"
    assert res["restarts"] == 2
    # steps after the checkpoint but before the crash are re-run: step 5 is
    # logged twice (lost work replayed from the step-4 checkpoint)
    steps_logged = [m["step"] for m in t.metrics_log if "step" in m]
    assert steps_logged.count(5) >= 2
    assert sorted(set(steps_logged)) == list(range(12))


def test_trainer_gives_up_after_max_restarts(tmp_path):
    t = _tiny_trainer(tmp_path, failure_at={1, 2, 3, 4, 5}, steps=8)
    t.cfg.max_restarts = 2
    with pytest.raises(StepFailure):
        t.run()


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)), jnp.float32)
    q, s = comp.quantize_int8(x)
    err = np.abs(np.asarray(comp.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF: the *accumulated* compressed signal tracks the accumulated true
    signal (residual stays bounded)."""
    rng = np.random.default_rng(0)
    state = comp.ef_init((256,))
    total_true = np.zeros((256,))
    total_sent = np.zeros((256,))
    for i in range(60):
        g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
        sent, state = comp.compress_decompress(g, state)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    resid = np.abs(total_true - total_sent)
    # residual equals the carried error, which is bounded by one quant step
    assert resid.max() < 0.2


def test_compressed_psum_matches_psum():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((1, 1))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)

    def f(xs):
        out, _ = comp.compressed_psum(xs, "data", comp.ef_init(xs.shape))
        return out

    y = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                  check_rep=False)(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# data + serving
# ---------------------------------------------------------------------------


def test_gaussian_blobs_shapes():
    x, c = gaussian_blobs(1000, n=10, k=10, noise_points=100, seed=0)
    assert x.shape == (1100, 10)
    assert c.shape == (10, 10)


def test_blob_stream_is_stationary():
    g1 = blob_stream(512, seed=3)
    g2 = blob_stream(512, seed=3)
    a, b = next(g1), next(g2)
    np.testing.assert_allclose(a, b)


def test_token_batches_bounds():
    it = token_batches(100, 4, 8, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 8)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_serving_engine_completes_requests():
    cfg = get_config("qwen3-0.6b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_tokens=4)
        for i in range(5)
    ]
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    eng.run(reqs, max_steps=100)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
