"""Benchmark orchestrator. One function per paper table.

Prints ``name,us_per_call,derived`` CSV rows:
  * per paper table: us_per_call = median wall time of the winning algorithm
    on that row, derived = its relative accuracy eps (%);
  * kernel rows: FlashAssign timing per implementation (``ref`` always,
    ``interpret`` to exercise the Pallas kernel body, ``pallas`` compiled
    when a TPU backend is attached), derived = points/s;
  * stream_throughput rows: end-to-end ``fit_stream`` points/s over an
    ingest-latency-bound window reader, synchronous vs prefetch+donation
    (the ``/speedup`` row's derived is the ratio, higher is better);
  * roofline rows (if dry-run artifacts exist): derived = dominant-term
    seconds per step.

Scale knob: REPRO_BENCH_SCALE (default 0.5 — CPU container).
Section filter: REPRO_BENCH_SECTIONS, a comma list of
``kernels,stream,tables,scaling,fig3,roofline`` (default: all). CI's bench
job runs ``kernels,stream`` at tiny scale and diffs against the committed
baseline (benchmarks/diff.py).

Besides the CSV on stdout, results are written machine-readably to
``BENCH_hpclust.json`` (override with REPRO_BENCH_JSON) as
``{name: {"us_per_call": ..., "derived": ...}}`` for diffing across runs.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _rows_table3_4(scale):
    from benchmarks import tables

    for ds, strat, eps, t in tables.table3_4(n_exec=2, scale=scale):
        yield (f"table3_strategy_eps/{ds}/{strat}", t * 1e6, eps)


def _rows_table5_6(scale):
    from benchmarks import tables

    for ds, algo, eps, t in tables.table5_6(n_exec=2, scale=scale):
        yield (f"table5_vs_baselines/{ds}/{algo}", t * 1e6, eps)


def _rows_table7_8():
    from benchmarks import tables

    for m, algo, eps, t in tables.table7_8(max_pow=10, n_exec=1):
        yield (f"table7_scaling/m{m}/{algo}", t * 1e6, eps)


def _kernel_impls():
    import jax

    impls = ["ref", "interpret"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    return impls


def _rows_kernels(scale):
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    shapes = ((4096, 16, 64), (8192, 64, 256))
    if scale < 0.5:  # tiny/CI scale: one shape keeps interpret mode cheap
        shapes = shapes[:1]
    for s, k, d in shapes:
        x = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
        for impl in _kernel_impls():
            fn = lambda: ops.assign_clusters(x, c, impl=impl)[0].block_until_ready()
            fn()
            t0 = time.time()
            n = 5 if impl == "ref" else 3
            for _ in range(n):
                fn()
            us = (time.time() - t0) / n * 1e6
            yield (f"kernel_assign/{impl}/s{s}k{k}d{d}", us, s / (us / 1e6))


def _rows_stream(scale):
    """End-to-end fit_stream throughput: synchronous vs prefetch+donation.

    The reader serves PRE-STAGED windows behind an emulated per-window fetch
    latency (``io_s``) — the shape of the paper's infinitely-tall regime,
    where windows arrive from storage/network, not from an in-process
    generator. Prefetch overlaps that latency (plus sanitize + H2D) with
    device compute; donation reuses the state buffers across windows. The
    single-core CPU container cannot overlap CPU-bound synthesis with
    CPU-bound XLA compute, so synthesizing data inside the reader would
    measure core contention, not the engine.
    """
    import numpy as np

    from repro.core.hpclust import HPClust
    from repro.core.strategies import HPClustConfig
    from repro.data.pipeline import blob_stream

    cfg = HPClustConfig(k=10, sample_size=2048, workers=4, rounds=4)
    big = scale >= 0.5
    window = 1 << 17 if big else 1 << 15
    n_windows = 8 if big else 4
    io_s = 0.06
    reps = 3 if big else 2

    gen = blob_stream(window, n=10, k=10, seed=1)
    bufs = [np.asarray(next(gen), np.float32) for _ in range(3)]

    def reader():
        for i in range(n_windows):
            time.sleep(io_s)  # emulated shard-fetch latency
            yield bufs[i % len(bufs)]

    def run(prefetch: int, donate: bool) -> float:
        os.environ["REPRO_DONATE"] = "1" if donate else "0"
        try:
            hp = HPClust(cfg, seed=0, prefetch=prefetch)
            t0 = time.perf_counter()
            hp.fit_stream(reader())
            return time.perf_counter() - t0
        finally:
            os.environ.pop("REPRO_DONATE", None)

    run(0, False)  # warm the compile caches
    t_sync = min(run(0, False) for _ in range(reps))
    t_pref = min(run(2, True) for _ in range(reps))
    points = window * n_windows
    yield ("stream_throughput/sync", t_sync * 1e6, points / t_sync)
    yield ("stream_throughput/prefetch_donate", t_pref * 1e6, points / t_pref)
    yield ("stream_throughput/speedup", t_pref * 1e6, t_sync / t_pref)


def _rows_fig3():
    from benchmarks import tables

    for strat, w, eps, t in tables.fig3_workers(n_exec=1):
        yield (f"fig3_workers/{strat}/w{w}", t * 1e6, eps)


def _rows_roofline():
    try:
        from benchmarks import roofline

        rows = roofline.build_table()
    except Exception as e:  # pragma: no cover
        print(f"# roofline section unavailable: {e!r}", file=sys.stderr)
        return
    for r in rows:
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        yield (
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r['dominant']}",
            t_dom * 1e6,
            r["roofline_fraction"],
        )


def main() -> None:
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "0.5"))
    json_path = os.environ.get("REPRO_BENCH_JSON", "BENCH_hpclust.json")
    wanted = os.environ.get("REPRO_BENCH_SECTIONS", "")
    wanted = {s.strip() for s in wanted.split(",") if s.strip()} or None
    sections = [
        ("kernels", lambda: _rows_kernels(scale)),
        ("stream", lambda: _rows_stream(scale)),
        ("tables", lambda: _rows_table3_4(scale)),
        ("tables", lambda: _rows_table5_6(scale)),
        ("scaling", _rows_table7_8),
        ("fig3", _rows_fig3),
        ("roofline", _rows_roofline),
    ]
    print("name,us_per_call,derived")
    results: dict[str, dict[str, float]] = {}
    for label, make_rows in sections:
        if wanted is not None and label not in wanted:
            continue
        for name, us, derived in make_rows():
            print(f"{name},{us:.1f},{derived:.4f}")
            sys.stdout.flush()
            results[name] = {"us_per_call": round(us, 1),
                             "derived": round(float(derived), 4)}
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump(results, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {len(results)} result(s) to {json_path}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
