"""Benchmark orchestrator. One function per paper table.

Prints ``name,us_per_call,derived`` CSV rows:
  * per paper table: us_per_call = median wall time of the winning algorithm
    on that row, derived = its relative accuracy eps (%);
  * kernel rows: FlashAssign interpret-vs-ref timing at several shapes,
    derived = points/s;
  * roofline rows (if dry-run artifacts exist): derived = dominant-term
    seconds per step.

Scale knob: REPRO_BENCH_SCALE (default 0.5 — CPU container).

Besides the CSV on stdout, results are written machine-readably to
``BENCH_hpclust.json`` (override with REPRO_BENCH_JSON) as
``{name: {"us_per_call": ..., "derived": ...}}`` for diffing across runs.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _rows_table3_4(scale):
    from benchmarks import tables

    for ds, strat, eps, t in tables.table3_4(n_exec=2, scale=scale):
        yield (f"table3_strategy_eps/{ds}/{strat}", t * 1e6, eps)


def _rows_table5_6(scale):
    from benchmarks import tables

    for ds, algo, eps, t in tables.table5_6(n_exec=2, scale=scale):
        yield (f"table5_vs_baselines/{ds}/{algo}", t * 1e6, eps)


def _rows_table7_8():
    from benchmarks import tables

    for m, algo, eps, t in tables.table7_8(max_pow=10, n_exec=1):
        yield (f"table7_scaling/m{m}/{algo}", t * 1e6, eps)


def _rows_kernels():
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    for s, k, d in ((4096, 16, 64), (8192, 64, 256)):
        x = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
        for impl in ("ref",):
            fn = lambda: ops.assign_clusters(x, c, impl=impl)[0].block_until_ready()
            fn()
            t0 = time.time()
            n = 5
            for _ in range(n):
                fn()
            us = (time.time() - t0) / n * 1e6
            yield (f"kernel_assign/{impl}/s{s}k{k}d{d}", us, s / (us / 1e6))


def _rows_fig3():
    from benchmarks import tables

    for strat, w, eps, t in tables.fig3_workers(n_exec=1):
        yield (f"fig3_workers/{strat}/w{w}", t * 1e6, eps)


def _rows_roofline():
    try:
        from benchmarks import roofline

        rows = roofline.build_table()
    except Exception as e:  # pragma: no cover
        print(f"# roofline section unavailable: {e!r}", file=sys.stderr)
        return
    for r in rows:
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        yield (
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r['dominant']}",
            t_dom * 1e6,
            r["roofline_fraction"],
        )


def main() -> None:
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "0.5"))
    json_path = os.environ.get("REPRO_BENCH_JSON", "BENCH_hpclust.json")
    print("name,us_per_call,derived")
    sections = [
        _rows_kernels(),
        _rows_table3_4(scale),
        _rows_table5_6(scale),
        _rows_table7_8(),
        _rows_fig3(),
        _rows_roofline(),
    ]
    results: dict[str, dict[str, float]] = {}
    for rows in sections:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.4f}")
            sys.stdout.flush()
            results[name] = {"us_per_call": round(us, 1),
                             "derived": round(float(derived), 4)}
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump(results, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {len(results)} result(s) to {json_path}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
