"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md SSRoofline).

Per (arch x shape x mesh) cell, from experiments/dryrun/*.json:

  compute    = HLO_FLOPs / (chips x 197e12)           [bf16 peak / chip]
  memory     = HLO_bytes / (chips x 819e9)            [HBM BW / chip]
  collective = collective_bytes / link_bw             [~50 GB/s/link ICI]

cost_analysis() runs on the post-SPMD per-device module, so HLO_FLOPs and
HLO_bytes are already per-device: divide by per-chip peaks only (the
formulas above keep the assignment's chips-normalised form by treating the
recorded numbers as global/chips). Collective bytes are per-device operand
bytes on the wire; ring-algorithm multipliers (~2(N-1)/N) are *not* applied
— recorded as a stated assumption.

MODEL_FLOPS = 6 N D (train) / 2 N D (prefill/decode), N = active params.
The MODEL/HLO ratio measures how much compiled compute is "useful"
(attention, remat recompute, MoE dispatch and optimizer all make HLO larger
than 6ND; a ratio far below ~0.5 flags waste).
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}
TRAIN_MULT = {"train_4k": 6, "prefill_32k": 2, "decode_32k": 2, "long_500k": 2}


def active_params(arch: str) -> float:
    """Active (per-token) parameter count — MoE experts scaled by top_k/E."""
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config(arch)
    shapes = M.flat_table(cfg)
    total = 0.0
    for name, (shape, _, _) in shapes.items():
        n = 1.0
        for d in shape:
            n *= d
        if "|moe/w" in name and cfg.n_experts:
            n *= cfg.top_k / cfg.n_experts
        total += n
    return total


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape, chips = rec["arch"], rec["shape"], rec["chips"]
    cc = rec.get("cost_calibrated") or {}
    flops = cc.get("flops") or rec["cost"]["flops"]
    mem_bytes = cc.get("bytes") or rec["cost"]["bytes_accessed"]
    coll = cc.get("collective_bytes_total",
                  rec.get("collective_bytes_total", 0))
    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    # Optimistic memory floor: params/opt/batch read + outputs written once —
    # what a fully-fused TPU compile would stream from HBM. The raw HLO
    # bytes term (above) is the unfused upper bound (CPU-backend compile).
    mem = rec.get("memory_analysis", {})
    floor_bytes = (
        mem.get("argument_size_in_bytes", 0) + mem.get("output_size_in_bytes", 0)
    ) if mem.get("available") else rec.get("arg_bytes_per_device", 0)
    t_memory_floor = floor_bytes / HBM_BW
    terms_opt = {"compute": t_compute, "memory": t_memory_floor,
                 "collective": t_coll}
    dominant_opt = max(terms_opt, key=terms_opt.get)
    n_active = active_params(arch)
    model_flops = TRAIN_MULT[shape] * n_active * TOKENS[shape] / chips
    ratio = model_flops / max(flops, 1e-30)
    # roofline fraction: useful model flops per chip-second at the bound.
    # Under the *optimistic* memory floor (headline number); the raw-bytes
    # bound is reported alongside.
    t_bound = max(terms_opt.values())
    frac = (model_flops / PEAK_FLOPS) / max(t_bound, 1e-30)
    frac_raw = (model_flops / PEAK_FLOPS) / max(max(terms.values()), 1e-30)
    # decode cells are bandwidth-bound by physics: report bandwidth utility
    # (useful resident bytes touched once / HLO bytes) as their quality metric.
    bw_utility = floor_bytes / max(mem_bytes, 1e-30)
    out = {
        "arch": arch, "shape": shape, "mesh": rec["mesh"], "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_floor_s": t_memory_floor, "t_collective_s": t_coll,
        "dominant": dominant, "dominant_opt": dominant_opt,
        "model_flops_per_chip": model_flops, "hlo_flops_per_chip": flops,
        "model_over_hlo": ratio, "roofline_fraction": frac,
        "roofline_fraction_raw": frac_raw, "bw_utility": bw_utility,
    }
    if mem.get("available"):
        out["hbm_bytes_per_device"] = (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
        )
        out["fits_16gb"] = out["hbm_bytes_per_device"] < 16e9
    return out


def load_records(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return recs


def build_table(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    out = []
    for rec in load_records(dryrun_dir):
        if str(rec.get("arch", "")).startswith("hpclust"):
            continue  # paper-workload cells are analyzed in §Perf directly
        if not rec.get("cost_calibrated"):
            # multi-pod records are compile-proof only (uncalibrated scan
            # costs would yield bogus roofline terms) — single-pod table
            # per the assignment.
            continue
        row = analyze_cell(rec)
        if row:
            out.append(row)
    return out


def what_moves_it(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["model_over_hlo"] < 0.5:
            return ("compute-bound with low useful ratio: cut remat recompute "
                    "/ attention masking waste / MoE dispatch padding")
        return "compute-bound near-useful: only faster kernels / more chips help"
    if d == "memory":
        return ("HBM-bound: fuse/bf16-ify the dominant streams, shard the "
                "cache/state dims further, raise arithmetic intensity")
    return ("collective-bound: reshard to cut all-gathers (FSDP prefetch "
            "overlap), hierarchical reductions, int8 cross-pod compression")


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | mem s (raw/floor) | "
           "collective s | dominant (raw/opt) | 6ND/HLO | frac | fits 16GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.2e}/"
            f"{r['t_memory_floor_s']:.2e} | "
            f"{r['t_collective_s']:.3e} | {r['dominant']}/{r['dominant_opt']} | "
            f"{r['model_over_hlo']:.2f} | {r['roofline_fraction']:.2%} | "
            f"{r.get('fits_16gb', '-')} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    rows = build_table()
    Path("experiments").mkdir(exist_ok=True)
    Path("experiments/roofline.json").write_text(json.dumps(rows, indent=1))
    Path("experiments/roofline.md").write_text(render_markdown(rows))
    print(render_markdown(rows))
    for r in rows:
        print(f"{r['arch']} x {r['shape']}: {what_moves_it(r)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
