"""Benchmark regression gate: diff a fresh BENCH JSON against a baseline.

  PYTHONPATH=src python -m benchmarks.diff BENCH_hpclust.json \
      --baseline benchmarks/BENCH_baseline.json [--threshold 0.2] [--update]

Rules (per row name shared by both files):
  * timing rows compare ``us_per_call``: FAIL when the new time exceeds the
    baseline by more than ``--threshold`` (default 20%);
  * ``*/speedup`` rows compare ``derived`` the other way around (higher is
    better): FAIL when the new ratio drops below baseline*(1-threshold);
  * a baseline row missing from the new results FAILs (a silently dropped
    benchmark is itself a regression);
  * rows only in the new results are reported informationally — commit them
    into the baseline with ``--update``.

``--update`` rewrites the baseline from the new results and exits 0; run it
in the CI container (or an equally-provisioned box) so the committed numbers
match the environment the gate runs in. Exit status: 0 clean, 1 regressions.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path: str) -> dict:
    with open(path, encoding="utf-8") as fh:
        return json.load(fh)


def compare(new: dict, base: dict, threshold: float) -> tuple[list[str], list[str]]:
    """Return (failures, notes) comparing ``new`` results to ``base``."""
    failures: list[str] = []
    notes: list[str] = []
    for name in sorted(base):
        if name not in new:
            failures.append(f"{name}: missing from new results")
            continue
        if name.endswith("/speedup"):
            b, n = base[name]["derived"], new[name]["derived"]
            floor = b * (1.0 - threshold)
            line = f"{name}: speedup {b:.3f} -> {n:.3f} (floor {floor:.3f})"
            (failures if n < floor else notes).append(line)
        else:
            b, n = base[name]["us_per_call"], new[name]["us_per_call"]
            ceil = b * (1.0 + threshold)
            line = f"{name}: {b:.1f}us -> {n:.1f}us (ceiling {ceil:.1f}us)"
            (failures if n > ceil else notes).append(line)
    for name in sorted(set(new) - set(base)):
        notes.append(f"{name}: new row (not in baseline; use --update to add)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("results", help="fresh BENCH JSON (from benchmarks.run)")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON to gate against")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="allowed fractional regression (default 0.2 = 20%%)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the new results and exit")
    args = ap.parse_args(argv)

    new = _load(args.results)
    if args.update:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump(new, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"baseline updated: {args.baseline} ({len(new)} rows)")
        return 0

    base = _load(args.baseline)
    failures, notes = compare(new, base, args.threshold)
    for line in notes:
        print(f"  ok   {line}")
    for line in failures:
        print(f"  FAIL {line}", file=sys.stderr)
    if failures:
        print(f"{len(failures)} benchmark regression(s) "
              f"(threshold {args.threshold:.0%})", file=sys.stderr)
        return 1
    print(f"no regressions across {len(base)} baseline row(s) "
          f"(threshold {args.threshold:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
