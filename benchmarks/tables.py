"""Benchmarks mirroring the paper's tables at CPU scale.

One function per table family:
  * table3_4  — strategy comparison: relative accuracy eps (%) and
    convergence time per HPClust strategy (paper Tables 3/4).
  * table5_6  — HPClust-hybrid vs Forgy K-means vs PBK-BDC: eps and total
    time (paper Tables 5/6).
  * table7_8  — scaling experiment over m = 3^7..3^11 with 5% noise
    (paper Tables 7/8, Figures 4a/4b).

eps = 100 * (f - f*) / f* with f* = best objective observed across all
algorithms for that (dataset, k) — the paper's convention (its f* is the
historical best; ours is the run-local best, so eps >= 0 by construction).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import HPClust, HPClustConfig
from repro.core.baselines import forgy_kmeans, pbk_bdc
from repro.data import gaussian_blobs

STRATEGIES = ("inner", "competitive", "cooperative", "hybrid")


def _datasets(scale: float = 1.0):
    """Synthetic stand-ins spanning the paper's dim/size spectrum."""
    out = {}
    for name, (m, n, k, sig) in {
        "blobs-low-d": (int(20000 * scale), 4, 8, 1.0),
        "blobs-mid-d": (int(12000 * scale), 32, 5, 2.0),
        "blobs-high-d": (int(6000 * scale), 128, 5, 3.0),
    }.items():
        x, _ = gaussian_blobs(m, n=n, k=k, noise_points=int(m * 0.02),
                              sigma_max=sig, seed=hash(name) % 1000)
        out[name] = (x, k)
    return out


def _eps(objs: dict[str, float]) -> dict[str, float]:
    fstar = min(objs.values())
    return {a: 100.0 * (f - fstar) / max(fstar, 1e-12) for a, f in objs.items()}


def table3_4(n_exec: int = 3, scale: float = 1.0):
    """Returns rows: (dataset, strategy, eps_med, time_med)."""
    rows = []
    for ds, (x, k) in _datasets(scale).items():
        objs: dict[str, list[float]] = {s: [] for s in STRATEGIES}
        times: dict[str, list[float]] = {s: [] for s in STRATEGIES}
        for s in STRATEGIES:
            workers = 1 if s == "inner" else 4
            cfg = HPClustConfig(k=k, sample_size=min(1024, len(x) // 4),
                                workers=workers, rounds=6, strategy=s)
            for e in range(n_exec):
                hp = HPClust(cfg, seed=e)
                t0 = time.time()
                res = hp.fit(x)
                dt = time.time() - t0
                objs[s].append(hp.objective(x, res.centroids))
                times[s].append(dt)
        med_obj = {s: float(np.median(v)) for s, v in objs.items()}
        eps = _eps(med_obj)
        for s in STRATEGIES:
            rows.append((ds, s, eps[s], float(np.median(times[s]))))
    return rows


def table5_6(n_exec: int = 3, scale: float = 1.0):
    """HPClust-hybrid vs Forgy vs PBK-BDC. Rows: (dataset, algo, eps, t)."""
    rows = []
    for ds, (x, k) in _datasets(scale).items():
        objs, times = {}, {}
        per = {"hpclust-hybrid": [], "forgy": [], "pbk-bdc": []}
        pert = {a: [] for a in per}
        for e in range(n_exec):
            cfg = HPClustConfig(k=k, sample_size=min(1024, len(x) // 4),
                                workers=4, rounds=6, strategy="hybrid")
            hp = HPClust(cfg, seed=e)
            t0 = time.time(); r = hp.fit(x)
            pert["hpclust-hybrid"].append(time.time() - t0)
            per["hpclust-hybrid"].append(hp.objective(x, r.centroids))
            t0 = time.time(); fb = forgy_kmeans(x, k, seed=e)
            pert["forgy"].append(time.time() - t0)
            per["forgy"].append(fb.objective)
            t0 = time.time(); pb = pbk_bdc(x, k, segment_size=2048, seed=e)
            pert["pbk-bdc"].append(time.time() - t0)
            per["pbk-bdc"].append(pb.objective)
        med = {a: float(np.median(v)) for a, v in per.items()}
        eps = _eps(med)
        for a in per:
            rows.append((ds, a, eps[a], float(np.median(pert[a]))))
    return rows


def table7_8(max_pow: int = 11, n_exec: int = 2):
    """Scaling: m = 3^7 .. 3^max_pow, 10-dim, 10 blobs, 500 noise points.
    Rows: (m, algo, eps, t)."""
    rows = []
    for i in range(7, max_pow + 1):
        m = 3 ** i
        x, _ = gaussian_blobs(m, n=10, k=10, noise_points=500, seed=i)
        per = {"hpclust-hybrid": [], "hpclust-competitive": [], "forgy": [],
               "pbk-bdc": []}
        pert = {a: [] for a in per}
        s = min(5000, max(512, m - 1000))
        for e in range(n_exec):
            for strat in ("hybrid", "competitive"):
                cfg = HPClustConfig(k=10, sample_size=min(s, len(x) // 2),
                                    workers=4, rounds=6, strategy=strat)
                hp = HPClust(cfg, seed=e)
                t0 = time.time(); r = hp.fit(x)
                pert[f"hpclust-{strat}"].append(time.time() - t0)
                per[f"hpclust-{strat}"].append(hp.objective(x, r.centroids))
            t0 = time.time(); fb = forgy_kmeans(x, 10, seed=e)
            pert["forgy"].append(time.time() - t0)
            per["forgy"].append(fb.objective)
            t0 = time.time(); pb = pbk_bdc(x, 10, segment_size=4096, seed=e)
            pert["pbk-bdc"].append(time.time() - t0)
            per["pbk-bdc"].append(pb.objective)
        med = {a: float(np.median(v)) for a, v in per.items()}
        eps = _eps(med)
        for a in per:
            rows.append((m, a, eps[a], float(np.median(pert[a]))))
    return rows


def fig3_workers(n_exec: int = 2, workers=(1, 2, 4, 8)):
    """Figure 3 analogue: accuracy/time vs worker count (the paper's CPU
    count). Rows: (strategy, W, eps, t)."""
    x, _ = gaussian_blobs(16000, n=16, k=8, noise_points=200, seed=11)
    rows = []
    objs_all = {}
    times_all = {}
    for strat in ("competitive", "cooperative"):
        for w in workers:
            key = (strat, w)
            objs, times = [], []
            for e in range(n_exec):
                cfg = HPClustConfig(k=8, sample_size=1024, workers=w,
                                    rounds=6, strategy=strat)
                hp = HPClust(cfg, seed=e)
                t0 = time.time()
                r = hp.fit(x)
                times.append(time.time() - t0)
                objs.append(hp.objective(x, r.centroids))
            objs_all[key] = float(np.median(objs))
            times_all[key] = float(np.median(times))
    fstar = min(objs_all.values())
    for key, obj in objs_all.items():
        rows.append((key[0], key[1], 100 * (obj - fstar) / fstar,
                     times_all[key]))
    return rows
