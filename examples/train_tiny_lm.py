"""Train a tiny LM end-to-end with the full production stack: sharding-aware
step function, checkpoint/restart, failure injection, metrics.

  PYTHONPATH=src python examples/train_tiny_lm.py --steps 60
"""
import argparse
import shutil

import jax

from repro.configs import get_config
from repro.data import token_batches
from repro.launch import steps as S
from repro.models import model as M
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--inject-failure", type=int, default=25,
                    help="step at which to simulate a node crash (-1 = off)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    step_fn = jax.jit(S.make_train_step(cfg, lr_steps=args.steps, grad_accum=1))
    opt = step_fn.__wrapped__.optimizer

    def init_state():
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        return params, opt.init(params)

    shutil.rmtree("checkpoints/tiny_lm", ignore_errors=True)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=10,
                      ckpt_dir="checkpoints/tiny_lm"),
        step_fn, init_state, token_batches(cfg.vocab_size, 4, 32, seed=0),
        failure_at={args.inject_failure} if args.inject_failure >= 0 else None,
    )
    res = trainer.run()
    losses = [m["loss"] for m in trainer.metrics_log if "loss" in m]
    print(f"status={res['status']} steps={res['step']} "
          f"restarts={res['restarts']}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'no improvement'})")


if __name__ == "__main__":
    main()
