"""MSSC-ITD end-to-end: cluster an *infinite* data stream.

The stream never fits anywhere: windows arrive, HPClust workers keep
sampling and the incumbent only improves (keep-the-best). This is the
paper's e2e scenario, a few hundred optimization rounds total.

  PYTHONPATH=src python examples/infinite_stream.py
"""
import numpy as np

from repro.core import HPClust, HPClustConfig
from repro.core.hpclust import stream_from_generator
from repro.data import blob_stream


def main():
    cfg = HPClustConfig(
        k=10, sample_size=2048, workers=4, rounds=16, strategy="hybrid"
    )
    hp = HPClust(cfg, seed=0)

    windows = 16  # 16 windows x 16 rounds x 4 workers = 1024 subproblems
    stream = stream_from_generator(
        blob_stream(32768, n=10, k=10, seed=42), windows
    )
    res = hp.fit_stream(stream)

    hist = res.history.min(axis=1)  # best incumbent per round
    print("incumbent objective trajectory (every 16th round):")
    for r in range(0, len(hist), 16):
        print(f"  round {r:4d}: {hist[r]:.1f}")
    print(f"final sample objective: {res.objective:.1f}")

    holdout = next(iter(blob_stream(100000, n=10, k=10, seed=42)))
    print(f"holdout objective (100k fresh rows): "
          f"{hp.objective(holdout, res.centroids):.1f}")
    assert (np.diff(res.history, axis=0) <= 1e-3).all(), "monotonicity violated"
    print("keep-the-best monotonicity: OK")


if __name__ == "__main__":
    main()
