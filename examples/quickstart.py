"""Quickstart: cluster gaussian blobs with HPClust and compare strategies.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import HPClust, HPClustConfig
from repro.core.baselines import forgy_kmeans
from repro.data import gaussian_blobs


def main():
    x, centers = gaussian_blobs(20000, n=10, k=10, noise_points=500, seed=0)
    print(f"dataset: {x.shape[0]} points, {x.shape[1]} dims, k=10")

    results = {}
    for strategy in ("inner", "competitive", "cooperative", "hybrid"):
        cfg = HPClustConfig(
            k=10, sample_size=2048, workers=1 if strategy == "inner" else 4,
            rounds=6, strategy=strategy,
        )
        hp = HPClust(cfg, seed=0)
        res = hp.fit(x)
        results[strategy] = hp.objective(x, res.centroids)

    fb = forgy_kmeans(x, 10, seed=0)
    results["forgy-kmeans"] = fb.objective

    best = min(results.values())
    print(f"\n{'algorithm':16s} {'objective':>14s} {'eps %':>8s}")
    for name, obj in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"{name:16s} {obj:14.1f} {100*(obj-best)/best:8.2f}")


if __name__ == "__main__":
    main()
