"""HPClust x LM substrate: vector-quantize token embeddings of any --arch.

The paper's intro motivates MSSC for vector quantization / compression
(refs [4]); here the "infinitely tall data" is the stream of embedding rows
an LM produces. We train a smoke-scale LM for a few steps, then cluster its
token-embedding table with HPClust and report the quantization error and
codebook utilization.

  PYTHONPATH=src python examples/lm_embedding_clustering.py --arch qwen3-0.6b
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import HPClust, HPClustConfig
from repro.data import token_batches
from repro.launch import steps as S
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--codebook", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # brief training so embeddings aren't pure noise
    step = jax.jit(S.make_train_step(cfg, grad_accum=1))
    opt_state = step.__wrapped__.optimizer.init(params)
    data = token_batches(cfg.vocab_size, 4, 32, seed=0)
    for i in range(args.train_steps):
        params, opt_state, m = step(params, opt_state, next(data))
    print(f"trained {args.train_steps} steps, loss={float(m['loss']):.3f}")

    emb = np.asarray(params["top|embed"], np.float32)  # (V, d)
    print(f"clustering embedding table {emb.shape} into {args.codebook} codes")
    hp = HPClust(HPClustConfig(
        k=args.codebook, sample_size=min(256, len(emb) // 2), workers=4,
        rounds=8, strategy="hybrid",
    ), seed=0)
    res = hp.fit(emb)
    codes = hp.assign(emb, res.centroids)
    mse = hp.objective(emb, res.centroids) / emb.size
    util = len(np.unique(codes)) / args.codebook
    print(f"quantization MSE/dim: {mse:.6f}")
    print(f"codebook utilization: {util:.1%}")
    orig_bytes = emb.size * 4
    quant_bytes = len(emb) * 1 + res.centroids.size * 4
    print(f"compression: {orig_bytes/quant_bytes:.1f}x "
          f"({orig_bytes/1e6:.2f} MB -> {quant_bytes/1e6:.2f} MB)")


if __name__ == "__main__":
    main()
