from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    make_optimizer,
    clip_by_global_norm,
)
from repro.optim.schedule import cosine_schedule

__all__ = [
    "Optimizer", "adamw", "adafactor", "make_optimizer",
    "clip_by_global_norm", "cosine_schedule",
]
