"""Optimizers, from scratch (no optax dependency): AdamW and Adafactor.

AdamW keeps f32 (m, v) — the default for <=110B-class models on the
production mesh. Adafactor keeps a factored second moment (row/col vectors
for every >=2D weight) and no first moment — the standard mitigation for
671B-class models where AdamW state cannot fit 16 GB/chip HBM even fully
sharded (DESIGN.md SS4). Optimizer state inherits each parameter's
PartitionSpec (rows/cols inherit the matching single axis), so state shards
exactly like the weights (ZeRO-style by construction under GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, Array], tuple[PyTree, PyTree]]
    # state_specs(param_specs) -> state PartitionSpec pytree
    state_specs: Callable[[PyTree], PyTree]


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamState(NamedTuple):
    step: Array
    m: PyTree
    v: PyTree


def adamw(
    lr: float | Callable[[Array], Array] = 3e-4,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params), jax.tree.map(zeros, params))

    def update(grads, state, params, _unused_step=None):
        step = state.step + 1
        lr_t = lr_fn(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mh = m2 / b1c
            vh = v2 / b2c
            delta = mh / (jnp.sqrt(vh) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamState(step, new_m, new_v)

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P
        return AdamState(P(), jax.tree.map(lambda s: s, param_specs),
                         jax.tree.map(lambda s: s, param_specs))

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, beta1=0)
# ---------------------------------------------------------------------------


class FactoredSlot(NamedTuple):
    row: Array   # (..., n) mean over last dim
    col: Array   # (..., m) mean over second-to-last dim
    full: Array  # scalar-shaped placeholder or full v for <2D params


class AdafactorState(NamedTuple):
    step: Array
    slots: PyTree  # FactoredSlot per leaf


def adafactor(
    lr: float | Callable[[Array], Array] = 1e-2,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def slot(p):
            if _factored(p):
                return FactoredSlot(
                    row=jnp.zeros(p.shape[:-1], jnp.float32),
                    col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    full=jnp.zeros((), jnp.float32),
                )
            return FactoredSlot(
                row=jnp.zeros((), jnp.float32),
                col=jnp.zeros((), jnp.float32),
                full=jnp.zeros(p.shape, jnp.float32),
            )
        return AdafactorState(jnp.zeros((), jnp.int32), jax.tree.map(slot, params))

    def update(grads, state, params, _unused=None):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                row = beta2 * s.row + (1 - beta2) * jnp.mean(g2, axis=-1)
                col = beta2 * s.col + (1 - beta2) * jnp.mean(g2, axis=-2)
                rmean = jnp.mean(row, axis=-1, keepdims=True)
                vhat = (row / jnp.maximum(rmean, eps))[..., None] * col[..., None, :]
                u = g / jnp.sqrt(jnp.maximum(vhat, eps))
                new_s = FactoredSlot(row, col, s.full)
            else:
                full = beta2 * s.full + (1 - beta2) * g2
                u = g / jnp.sqrt(jnp.maximum(full, eps))
                new_s = FactoredSlot(s.row, s.col, full)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_s

        out = jax.tree.map(
            upd, grads, state.slots, params,
            is_leaf=lambda x: isinstance(x, FactoredSlot),
        )
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(
            x[1], FactoredSlot)
        new_p = jax.tree.map(lambda x: x[0], out, is_leaf=is_pair)
        new_s = jax.tree.map(lambda x: x[1], out, is_leaf=is_pair)
        return new_p, AdafactorState(step, new_s)

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def slot_spec(spec):
            axes = tuple(spec) if spec is not None else ()
            row = P(*axes[:-1]) if len(axes) >= 2 else P()
            col = P(*(axes[:-2] + axes[-1:])) if len(axes) >= 2 else P()
            full = P() if len(axes) >= 2 else (P(*axes) if axes else P())
            return FactoredSlot(row, col, full)

        return AdafactorState(
            P(), jax.tree.map(slot_spec, param_specs,
                              is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)),
        )

    return Optimizer(init, update, state_specs)


def make_optimizer(kind: str, lr=None) -> Optimizer:
    if kind == "adamw":
        return adamw(lr if lr is not None else 3e-4)
    if kind == "adafactor":
        return adafactor(lr if lr is not None else 1e-2)
    raise ValueError(kind)
