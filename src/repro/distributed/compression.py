"""Gradient compression for cross-pod reductions: int8 + error feedback.

On the 2x16x16 mesh, intra-pod gradient reduction rides 50 GB/s ICI links
but the cross-pod hop is the slow tier. The standard mitigation is lossy
compression with error feedback (EF-SGD): quantize (grad + carried error)
to int8 with a per-tensor scale, exchange the int8 payload (4x fewer
bytes), and carry the quantization residual into the next step, which keeps
the long-run bias at zero.

``compressed_psum`` is built for shard_map code: it all-gathers the int8
payloads over the named axis and sums after dequantization (summing int8
pre-reduction would overflow; gather+local-sum keeps the wire format int8,
which is where the 4x saving lives).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class EFState(NamedTuple):
    error: Array  # carried quantization residual, f32, same shape as grad


def ef_init(shape) -> EFState:
    return EFState(jnp.zeros(shape, jnp.float32))


def quantize_int8(x: Array) -> tuple[Array, Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: Array, state: EFState) -> tuple[Array, EFState]:
    """Single-node EF round-trip (what each participant applies locally)."""
    v = x.astype(jnp.float32) + state.error
    q, s = quantize_int8(v)
    deq = dequantize_int8(q, s)
    return deq, EFState(v - deq)


def compressed_psum(x: Array, axis: str, state: EFState) -> tuple[Array, EFState]:
    """EF int8 all-gather-sum over a named axis (use inside shard_map).

    Wire bytes: size(x)/4 + one f32 scale per participant, vs size(x) for a
    ring all-reduce of f32.
    """
    v = x.astype(jnp.float32) + state.error
    q, s = quantize_int8(v)
    deq_local = dequantize_int8(q, s)
    new_state = EFState(v - deq_local)
    qs = jax.lax.all_gather(q, axis)          # int8 payload on the wire
    ss = jax.lax.all_gather(s, axis)
    total = jnp.sum(
        qs.astype(jnp.float32)
        * ss.reshape((-1,) + (1,) * (qs.ndim - 1)),
        axis=0,
    )
    return total, new_state
