from repro.distributed.sharding import (
    logical_rules, param_shardings, batch_sharding, batch_spec,
    cache_sharding, dp_axes, dedupe_spec,
)

__all__ = [
    "logical_rules", "param_shardings", "batch_sharding", "batch_spec",
    "cache_sharding", "dp_axes", "dedupe_spec",
]
