"""Logical-axis -> mesh-axis rules (DP / FSDP / TP / EP / SP).

Parameter tables tag every dim with a logical axis; these rules map them to
the production mesh:

  vocab, heads, kv_heads, mlp, experts  -> "model"  (tensor / expert parallel)
  embed                                 -> "data" (single-pod) or
                                           ("pod","data") (multi-pod) — FSDP
  layers                                -> unsharded (scan axis)

Duplicate mesh axes inside one PartitionSpec are illegal; when a weight's
dims map to the same axis twice (e.g. expert FFN (experts, embed, mlp) ->
(model, data, model)), later occurrences are dropped (kept None) — the first
axis wins, which empirically keeps the larger dim sharded.

Batch/activation specs: tokens are sharded over ("pod","data") (DP). For
batch=1 long-context decode the KV cache sequence dim is sharded over
"data" instead (sequence parallelism); see cache_specs().
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def logical_rules(mesh: Mesh) -> dict[str, Any]:
    multi_pod = "pod" in mesh.axis_names
    fsdp = ("pod", "data") if multi_pod else "data"
    return {
        "vocab": "model",
        "embed": fsdp,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "layers": None,
    }


def dedupe_spec(spec: P) -> P:
    seen: set[str] = set()
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        keep = tuple(a for a in axes if a not in seen)
        seen.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    return P(*out)


def _drop_indivisible(spec: P, shape, mesh: Mesh) -> P:
    """Replicate dims whose size isn't divisible by their mesh axes (jit
    input shardings require exact divisibility — e.g. whisper's vocab 51865)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_shardings(cfg, mesh: Mesh) -> dict[str, NamedSharding]:
    from repro.models import model as M

    rules = logical_rules(mesh)
    specs = M.param_specs(cfg, rules)
    shapes = M.param_shapes(cfg)
    return {
        k: NamedSharding(
            mesh, _drop_indivisible(dedupe_spec(s), shapes[k].shape, mesh)
        )
        for k, s in specs.items()
    }


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(dp_axes(mesh), *()))


def batch_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh))


def cache_sharding(cfg, mesh: Mesh, cache, *, seq_parallel: bool) -> Any:
    """Shardings matching the init_cache pytree (leading dim = segment stack).

    Greedy, divisibility-checked policy:
      1. batch dim (index 1) over the DP axes when divisible;
      2. seq_parallel (batch=1 long-context): dim 2 — the cache sequence/
         state dim — over "data" when divisible (sequence parallelism);
      3. otherwise the largest remaining dim over "model" when divisible
         (keeps e.g. the mLSTM (H, hd, hd) matrix memory distributed).
    """
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    data_size = mesh.shape["data"]
    model_size = mesh.shape["model"]

    def spec_for(leaf):
        shape = leaf.shape
        axes: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dp_size == 0 and shape[1] > 0:
            axes[1] = dp
        elif seq_parallel and len(shape) >= 3 and shape[2] % data_size == 0:
            # batch=1 long-context: sequence over `data` (SP)
            axes[2] = "data"
        # Shard the trailing feature dim over `model` (Megatron-style decode:
        # scores psum over hd shards; ctx/wo stay row-sharded). Sharding the
        # *sequence* dim instead forces a full cache re-layout around every
        # dynamic_update_slice (observed +15 GB/device on qwen1.5 decode).
        if len(shape) >= 3 and shape[-1] % model_size == 0 \
                and shape[-1] >= 2 * model_size:
            axes[-1] = "model"
        if all(a is None for a in axes) and len(shape) >= 2:
            # nothing sharded yet: largest dim over model if divisible
            order = sorted(range(1, len(shape)), key=lambda i: -shape[i])
            for i in order:
                if shape[i] % model_size == 0 and shape[i] >= model_size:
                    axes[i] = "model"
                    break
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(spec_for, cache)
