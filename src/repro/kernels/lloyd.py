"""Fused Lloyd-pass Pallas kernel: assign + cluster-sums in ONE x read.

A Lloyd iteration needs (argmin over centroids) and (per-cluster sums).
Running FlashAssign then cluster-sum streams the points twice from HBM; at
clustering dimensions (k <= a few hundred, d <= a few thousand) the whole
(K, D) sums accumulator fits VMEM, so both halves fuse: for each point tile
we loop centroid tiles with the online argmin carry, and once the winner is
known we accumulate one-hot(winner)^T @ x into the resident (K, D) block.
Memory traffic per Lloyd iteration halves — the dominant term of the
hpclust-prod roofline cell (EXPERIMENTS.md §Perf It.3).

Constraint: D is untiled (the x row-block (bs, D) must fit VMEM — true for
the paper's regimes, d <= 5000). ops.lloyd_pass falls back to the two-kernel
path otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lloyd_kernel(
    cn_ref,     # (1, bk)  f32 centroid norms (+inf padding)
    x_ref,      # (bs, D)  f32 point tile (full D)
    c_ref,      # (bk, D)  f32 centroid tile
    idx_ref,    # out (bs, 1) int32
    dist_ref,   # out (bs, 1) f32
    sums_ref,   # out (K, D) f32 — constant index map, VMEM resident
    counts_ref, # out (K, 1) f32
    best_ref,   # scratch (bs, 1) f32
    bidx_ref,   # scratch (bs, 1) int32
    *,
    nk: int,
    bk: int,
    k_total: int,
    bs: int,
    s_valid: int,
):
    si = pl.program_id(0)
    ki = pl.program_id(1)

    x = x_ref[...]
    xf = x.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=1, keepdims=True)  # (bs, 1) — norms in f32
    dots = jax.lax.dot_general(
        x, c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bs, bk) — bf16 inputs still accumulate in f32
    d2 = jnp.maximum(xn - 2.0 * dots + cn_ref[...], 0.0)
    local_min = jnp.min(d2, axis=1, keepdims=True)
    local_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None] + ki * bk

    @pl.when(ki == 0)
    def _first():
        best_ref[...] = local_min
        bidx_ref[...] = local_arg

    @pl.when(ki > 0)
    def _online():
        take = local_min < best_ref[...]
        best_ref[...] = jnp.where(take, local_min, best_ref[...])
        bidx_ref[...] = jnp.where(take, local_arg, bidx_ref[...])

    @pl.when(ki == nk - 1)
    def _emit_and_accumulate():
        idx_ref[...] = bidx_ref[...]
        dist_ref[...] = best_ref[...]

        @pl.when(si == 0)
        def _init_outs():
            sums_ref[...] = jnp.zeros_like(sums_ref)
            counts_ref[...] = jnp.zeros_like(counts_ref)

        winners = bidx_ref[...]  # (bs, 1)
        kk = jax.lax.broadcasted_iota(jnp.int32, (1, k_total), 1)
        # Mask padding rows (global row id >= s_valid): they must not
        # contribute to sums/counts.
        row_id = si * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
        live = row_id < s_valid
        # One-hot in x's dtype so the MXU sees matching operands (0/1 are
        # exact in bf16); the dot still accumulates f32 into sums_ref.
        onehot = ((winners == kk) & live).astype(x.dtype)  # (bs, K)
        sums_ref[...] += jax.lax.dot_general(
            onehot, x, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # Counts reduce in f32: a bf16 running count saturates at 256.
        counts_ref[...] += jnp.sum(
            onehot.astype(jnp.float32), axis=0)[:, None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_valid", "s_valid", "block_s", "block_k", "compute_dtype",
        "interpret",
    ),
)
def lloyd_pass_pallas(
    x: jax.Array,
    c: jax.Array,
    *,
    k_valid: int | None = None,
    s_valid: int | None = None,
    block_s: int = 256,
    block_k: int = 128,
    compute_dtype: str = "f32",
    interpret: bool = False,
):
    """One fused Lloyd pass. x (s, d), c (k, d) padded to tile multiples.

    Returns (idx (s,), dist (s,), sums (k, d) f32, counts (k,) f32).
    ``compute_dtype="bf16"`` streams bf16 point/centroid tiles; norms,
    distances, sums and counts all still accumulate in f32.
    """
    s, d = x.shape
    k = c.shape[0]
    bs, bk = min(block_s, s), min(block_k, k)
    assert s % bs == 0 and k % bk == 0, (s, k, bs, bk)
    ns, nk = s // bs, k // bk

    cf = c.astype(jnp.float32)
    cn = jnp.sum(cf * cf, axis=1)[None, :]  # centroid norms stay f32
    if k_valid is not None and k_valid < k:
        cn = jnp.where(jnp.arange(k)[None, :] >= k_valid, jnp.inf, cn)
    if compute_dtype == "bf16":
        xk, ck = x.astype(jnp.bfloat16), cf.astype(jnp.bfloat16)
    else:
        xk, ck = x.astype(jnp.float32), cf

    kernel = functools.partial(
        _lloyd_kernel, nk=nk, bk=bk, k_total=k, bs=bs,
        s_valid=s_valid if s_valid is not None else s,
    )
    idx, dist, sums, counts = pl.pallas_call(
        kernel,
        grid=(ns, nk),
        in_specs=[
            pl.BlockSpec((1, bk), lambda si, ki: (0, ki)),
            pl.BlockSpec((bs, d), lambda si, ki: (si, 0)),
            pl.BlockSpec((bk, d), lambda si, ki: (ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bs, 1), lambda si, ki: (si, 0)),
            pl.BlockSpec((bs, 1), lambda si, ki: (si, 0)),
            pl.BlockSpec((k, d), lambda si, ki: (0, 0)),
            pl.BlockSpec((k, 1), lambda si, ki: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, 1), jnp.int32),
            jax.ShapeDtypeStruct((s, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bs, 1), jnp.float32),
            pltpu.VMEM((bs, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cn, xk, ck)
    return idx[:, 0], dist[:, 0], sums, counts[:, 0]
