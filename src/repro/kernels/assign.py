"""FlashAssign: fused pairwise-distance + running argmin Pallas TPU kernel.

The hot loop of every K-means-family algorithm (and the operation the paper's
SS5.3 vectorizes on CPU SIMD) is: for each point, find the nearest centroid.
The naive formulation materializes an (s, k) distance matrix in HBM; for the
paper's big-data regimes (s up to 1.3e5, k up to 25, d up to 5000 — and far
larger inside this framework) that matrix is pure memory traffic.

TPU adaptation: stream centroid tiles through VMEM and keep an *online*
(min, argmin) carry per point row — the same trick FlashAttention uses for
the online softmax, applied to argmin. The (s, k) matrix never exists.

Grid: (s/bs, k/bk, d/bd), d innermost so the (bs, bk) dot-product
accumulator lives in a VMEM scratch across d-tiles (MXU matmuls of shape
(bs, bd) x (bd, bk)). On the last d-tile the partial dots fold with the
precomputed row norms into squared distances, which update the per-row
running (best, best_idx) scratch across k-tiles. Outputs are written once,
on the final (k, d) tile.

All tile shapes are multiples of (8, 128) so both the MXU matmul and the
VPU select run on hardware-aligned lanes. Padding is handled by the ops.py
wrapper: K is padded with +inf norms (never wins), D with zeros (no-op in the
dot), S with arbitrary rows that are sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 256
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_D = 256


def _assign_kernel(
    xn_ref,  # (bs, 1)  f32  row norms ||x||^2
    cn_ref,  # (1, bk)  f32  centroid norms ||c||^2 (+inf on padding)
    x_ref,   # (bs, bd) f32/bf16 point tile
    c_ref,   # (bk, bd) f32/bf16 centroid tile
    idx_ref,   # out (bs, 1) int32
    dist_ref,  # out (bs, 1) f32
    acc_ref,   # scratch (bs, bk) f32 — partial 2*x.c
    best_ref,  # scratch (bs, 1) f32 — running min distance
    bidx_ref,  # scratch (bs, 1) int32 — running argmin
    *,
    nk: int,
    nd: int,
    bk: int,
):
    ki = pl.program_id(1)
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bs, bd) x (bk, bd)^T on the MXU, f32 accumulation.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(di == nd - 1)
    def _fold_distances():
        # ||x||^2 - 2 x.c + ||c||^2, clamped at 0.
        d2 = jnp.maximum(xn_ref[...] - 2.0 * acc_ref[...] + cn_ref[...], 0.0)
        local_min = jnp.min(d2, axis=1, keepdims=True)  # (bs, 1)
        local_arg = (
            jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None] + ki * bk
        )  # (bs, 1) global centroid index

        @pl.when(ki == 0)
        def _first_tile():
            best_ref[...] = local_min
            bidx_ref[...] = local_arg

        @pl.when(ki > 0)
        def _online_min():
            take_new = local_min < best_ref[...]
            best_ref[...] = jnp.where(take_new, local_min, best_ref[...])
            bidx_ref[...] = jnp.where(take_new, local_arg, bidx_ref[...])

        @pl.when(ki == nk - 1)
        def _emit():
            idx_ref[...] = bidx_ref[...]
            dist_ref[...] = best_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_valid", "block_s", "block_k", "block_d", "compute_dtype",
        "interpret",
    ),
)
def assign_pallas(
    x: jax.Array,
    c: jax.Array,
    *,
    k_valid: int | None = None,
    block_s: int = DEFAULT_BLOCK_S,
    block_k: int = DEFAULT_BLOCK_K,
    block_d: int = DEFAULT_BLOCK_D,
    compute_dtype: str = "f32",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment. x: (s, d), c: (k, d) -> (idx, dist).

    Inputs must already be padded to tile multiples (ops.py does this);
    ``k_valid`` marks how many leading rows of ``c`` are real — padded rows
    get +inf norms so they can never win the argmin.

    ``compute_dtype="bf16"`` feeds the MXU bf16 point/centroid tiles (half
    the VMEM traffic) while norms and the distance accumulator stay f32 —
    the dot itself always uses ``preferred_element_type=f32``.
    """
    s, d = x.shape
    k, d2 = c.shape
    assert d == d2, (x.shape, c.shape)
    bs, bk, bd = min(block_s, s), min(block_k, k), min(block_d, d)
    assert s % bs == 0 and k % bk == 0 and d % bd == 0, (
        f"padded shapes required: {(s, k, d)} vs blocks {(bs, bk, bd)}"
    )
    ns, nk, nd = s // bs, k // bk, d // bd

    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=1, keepdims=True)  # (s, 1) — norms stay f32
    cn = jnp.sum(cf * cf, axis=1)[None, :]  # (1, k)
    if k_valid is not None and k_valid < k:
        pad_mask = jnp.arange(k)[None, :] >= k_valid
        cn = jnp.where(pad_mask, jnp.inf, cn)
    if compute_dtype == "bf16":
        xk, ck = xf.astype(jnp.bfloat16), cf.astype(jnp.bfloat16)
    else:
        xk, ck = xf, cf

    kernel = functools.partial(_assign_kernel, nk=nk, nd=nd, bk=bk)
    idx, dist = pl.pallas_call(
        kernel,
        grid=(ns, nk, nd),
        in_specs=[
            pl.BlockSpec((bs, 1), lambda si, ki, di: (si, 0)),
            pl.BlockSpec((1, bk), lambda si, ki, di: (0, ki)),
            pl.BlockSpec((bs, bd), lambda si, ki, di: (si, di)),
            pl.BlockSpec((bk, bd), lambda si, ki, di: (ki, di)),
        ],
        out_specs=[
            pl.BlockSpec((bs, 1), lambda si, ki, di: (si, 0)),
            pl.BlockSpec((bs, 1), lambda si, ki, di: (si, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, 1), jnp.int32),
            jax.ShapeDtypeStruct((s, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bs, bk), jnp.float32),
            pltpu.VMEM((bs, 1), jnp.float32),
            pltpu.VMEM((bs, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xn, cn, xk, ck)
    return idx[:, 0], dist[:, 0]
