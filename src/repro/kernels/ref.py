"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every Pallas kernel in this package is
validated against these functions (interpret mode on CPU, compiled on TPU).
They are also the lowering path used by the CPU-simulated multi-pod dry-runs,
so they must be shardable, numerically robust and free of host callbacks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_sq_dists(x: Array, c: Array) -> Array:
    """Squared Euclidean distances between rows of x (s,d) and c (k,d) -> (s,k).

    Uses the expanded form ||x||^2 - 2 x.c + ||c||^2 with f32 accumulation,
    clamped at zero (the expansion can go slightly negative in floating point).
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)  # (s, 1)
    cc = jnp.sum(c * c, axis=-1)  # (k,)
    d2 = xx - 2.0 * (x @ c.T) + cc[None, :]
    return jnp.maximum(d2, 0.0)


def assign_ref(x: Array, c: Array) -> tuple[Array, Array]:
    """Nearest-centroid assignment.

    Args:
      x: (s, d) points.
      c: (k, d) centroids.
    Returns:
      idx:  (s,) int32 index of nearest centroid.
      dist: (s,) f32 squared distance to that centroid.
    """
    d2 = pairwise_sq_dists(x, c)
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    dist = jnp.take_along_axis(d2, idx[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return idx, dist


def assign_ref_batched(x: Array, c: Array, batch: int = 65536) -> tuple[Array, Array]:
    """assign_ref evaluated in row batches via lax.map (bounds peak memory).

    For big s*k this avoids materializing the full (s,k) distance matrix —
    the jnp analogue of the FlashAssign kernel's memory behaviour.
    """
    s = x.shape[0]
    if s <= batch:
        return assign_ref(x, c)
    pad = (-s) % batch
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xb = xp.reshape(-1, batch, x.shape[1])
    idx, dist = jax.lax.map(lambda xi: assign_ref(xi, c), xb)
    return idx.reshape(-1)[:s], dist.reshape(-1)[:s]


def cluster_sums_ref(x: Array, idx: Array, k: int) -> tuple[Array, Array]:
    """Per-cluster sums and counts.

    Args:
      x:   (s, d) points.
      idx: (s,) int32 cluster assignment in [0, k).
    Returns:
      sums:   (k, d) f32 per-cluster coordinate sums.
      counts: (k,)  f32 per-cluster point counts.
    """
    onehot = jax.nn.one_hot(idx, k, dtype=jnp.float32)  # (s, k)
    sums = onehot.T @ x.astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def lloyd_update_ref(x: Array, c: Array) -> tuple[Array, Array, Array, Array]:
    """One Lloyd iteration: assign + recompute means.

    Empty (degenerate) clusters keep their previous centroid and are flagged.

    Returns:
      new_c:    (k, d) f32 updated centroids.
      obj:      ()    f32 sum of squared distances under the *old* centroids.
      counts:   (k,)  f32 cluster sizes.
      degenerate: (k,) bool mask of empty clusters.
    """
    k = c.shape[0]
    idx, dist = assign_ref(x, c)
    sums, counts = cluster_sums_ref(x, idx, k)
    degenerate = counts == 0
    denom = jnp.maximum(counts, 1.0)[:, None]
    new_c = jnp.where(degenerate[:, None], c.astype(jnp.float32), sums / denom)
    return new_c, jnp.sum(dist), counts, degenerate


def mssc_objective_ref(x: Array, c: Array) -> Array:
    """f(C, X) = sum_i min_j ||x_i - c_j||^2 (Equation 1 of the paper)."""
    _, dist = assign_ref(x, c)
    return jnp.sum(dist)
