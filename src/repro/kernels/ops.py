"""Dispatching wrappers around the Pallas kernels.

``impl`` resolution:
  - "pallas":    compiled TPU kernel (requires a TPU backend).
  - "interpret": Pallas interpret mode — used by the CPU test suite.
  - "ref":       the jnp oracle (what XLA lowers on CPU / in dry-runs).
  - None/"auto": "pallas" on TPU, "ref" elsewhere.

The wrappers own all padding so the kernels can assume hardware-aligned
tiles: S is padded with junk rows (sliced off), D with zero columns (no-op in
dot products), K with +inf-norm centroids (can never win an argmin) /
out-of-range assignments (fall outside every one-hot tile).

Tile sizes come from ``repro.kernels.autotune`` when ``REPRO_AUTOTUNE`` is
enabled (persisted per backend/shape-bucket/dtype) and fall back to the
static heuristics in ``_heuristic_blocks`` otherwise. ``compute_dtype``
(argument or ``REPRO_COMPUTE_DTYPE=bf16``) switches the assign/lloyd kernels
to bf16 inputs with f32 accumulation; it is a *static* jit argument so each
dtype gets its own compile-cache entry.

Observability: each public wrapper opens a host-side ``kernel.*`` span when a
``repro.obs`` recorder is active AND the call is a real dispatch (arguments
are concrete, not tracers — inside an enclosing jit the wrapper runs at
trace time, where host timing is meaningless). The jitted bodies carry
``jax.named_scope`` labels so the regions survive into HLO metadata and XLA
profiles regardless. Dispatch is asynchronous, so a kernel span measures
dispatch cost unless the recorder was configured with ``sync_kernels=True``
(then the span blocks on the result — true execution time, at the price of a
pipeline bubble).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import flags, obs
from repro.kernels import autotune, ref
from repro.kernels.assign import assign_pallas
from repro.kernels.update import cluster_sums_pallas
from repro.obs import jaxhooks

Array = jax.Array

_LANE = 128
_SUBLANE = 8  # f32; bf16 tiles need 16 sublanes


def resolve_impl(impl: str | None) -> str:
    if impl in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl


def _round_up(v: int, m: int) -> int:
    return v + (-v) % m


def _sublane(compute_dtype: str) -> int:
    return 16 if compute_dtype == "bf16" else _SUBLANE


def _heuristic_blocks(kernel: str, s: int, k: int, d: int,
                      compute_dtype: str) -> tuple[int, int, int]:
    """The static tile defaults (used when autotune is off or misses).

    ``block_k`` is always one lane tile: K is lane-padded to >= 128, so a
    bigger k-block only helps once K itself exceeds 128 — exactly what the
    autotuner probes. ``block_s``/``block_d`` shrink to the (aligned) data so
    tiny problems don't pad to a full default tile.
    """
    sub = _sublane(compute_dtype)
    if kernel == "update":
        bs = min(512, _round_up(s, sub))
    else:
        bs = min(256, _round_up(s, sub))
    bd = min(512, _round_up(d, _LANE))
    return bs, _LANE, bd


def _blocks(kernel: str, s: int, k: int, d: int,
            compute_dtype: str) -> tuple[int, int, int]:
    tuned = autotune.lookup(kernel, s, k, d, dtype=compute_dtype)
    if tuned is None:
        return _heuristic_blocks(kernel, s, k, d, compute_dtype)
    bs, bk, bd = tuned
    sub = _sublane(compute_dtype)
    # Sanitize a cache entry written by another backend/version: alignment is
    # a hard kernel requirement, tune quality is not.
    return _round_up(bs, sub), _round_up(bk, _LANE), _round_up(bd, _LANE)


def _traced_call(rec, name: str, attrs: dict, thunk):
    """One host-side kernel span around a dispatch. The span covers dispatch
    only (async) unless the recorder asks for ``sync_kernels`` — then it
    blocks on the result and covers execution."""
    with rec.span(name, **attrs), jaxhooks.trace_annotation(name):
        out = thunk()
        if rec.sync_kernels:
            jax.block_until_ready(out)
    return out


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


@functools.partial(jax.jit, static_argnames=("impl", "compute_dtype"))
def _assign_clusters_jit(
    x: Array, c: Array, *, impl: str | None = None, compute_dtype: str = "f32",
) -> tuple[Array, Array]:
    with jaxhooks.named_scope("kernel.assign"):
        impl = resolve_impl(impl)
        if impl == "ref":
            return ref.assign_ref(x, c)
        s, d = x.shape
        k = c.shape[0]
        bs, bk, bd = _blocks("assign", s, k, d, compute_dtype)
        sp, kp, dp = _round_up(s, bs), _round_up(k, bk), _round_up(d, bd)
        xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
        cp = jnp.pad(c, ((0, kp - k), (0, dp - d)))
        idx, dist = assign_pallas(
            xp, cp, k_valid=k, block_s=bs, block_k=bk, block_d=bd,
            compute_dtype=compute_dtype, interpret=(impl == "interpret"),
        )
        return idx[:s], dist[:s]


def assign_clusters(
    x: Array, c: Array, *, impl: str | None = None,
    compute_dtype: str | None = None,
) -> tuple[Array, Array]:
    """Nearest-centroid assignment: x (s,d), c (k,d) -> (idx (s,), dist (s,))."""
    cdt = flags.compute_dtype(compute_dtype)
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _assign_clusters_jit(x, c, impl=impl, compute_dtype=cdt)
    return _traced_call(
        rec, "kernel.assign", {"s": int(x.shape[0]), "k": int(c.shape[0])},
        lambda: _assign_clusters_jit(x, c, impl=impl, compute_dtype=cdt),
    )


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def _cluster_sums_jit(x: Array, idx: Array, k: int, *, impl: str | None = None) -> tuple[Array, Array]:
    with jaxhooks.named_scope("kernel.update"):
        impl = resolve_impl(impl)
        if impl == "ref":
            return ref.cluster_sums_ref(x, idx, k)
        s, d = x.shape
        bs, bk, bd = _blocks("update", s, k, d, "f32")
        sp, dp = _round_up(s, bs), _round_up(d, bd)
        kp = _round_up(k, bk)
        # Padding rows get assignment kp (out of range of every tile).
        idxp = jnp.pad(idx.astype(jnp.int32), (0, sp - s), constant_values=kp)
        xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
        sums, counts = cluster_sums_pallas(
            xp, idxp, k, block_s=bs, block_k=bk, block_d=bd,
            interpret=(impl == "interpret"),
        )
        return sums[:, :d], counts


def cluster_sums(x: Array, idx: Array, k: int, *, impl: str | None = None) -> tuple[Array, Array]:
    """Per-cluster sums (k,d) and counts (k,) from assignments idx (s,)."""
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _cluster_sums_jit(x, idx, k, impl=impl)
    return _traced_call(
        rec, "kernel.update", {"s": int(x.shape[0]), "k": k},
        lambda: _cluster_sums_jit(x, idx, k, impl=impl),
    )


@functools.partial(jax.jit, static_argnames=("impl", "compute_dtype"))
def _mssc_objective_jit(
    x: Array, c: Array, *, impl: str | None = None, compute_dtype: str = "f32",
) -> Array:
    with jaxhooks.named_scope("kernel.objective"):
        _, dist = assign_clusters(x, c, impl=impl, compute_dtype=compute_dtype)
        return jnp.sum(dist)


def mssc_objective(
    x: Array, c: Array, *, impl: str | None = None,
    compute_dtype: str | None = None,
) -> Array:
    """Equation (1): sum of squared distances to nearest centroids."""
    cdt = flags.compute_dtype(compute_dtype)
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _mssc_objective_jit(x, c, impl=impl, compute_dtype=cdt)
    return _traced_call(
        rec, "kernel.objective", {"s": int(x.shape[0]), "k": int(c.shape[0])},
        lambda: _mssc_objective_jit(x, c, impl=impl, compute_dtype=cdt),
    )


@functools.partial(jax.jit, static_argnames=("impl", "compute_dtype"))
def _lloyd_pass_jit(
    x: Array, c: Array, *, impl: str | None = None, compute_dtype: str = "f32",
):
    with jaxhooks.named_scope("kernel.lloyd_pass"):
        impl = resolve_impl(impl)
        s, d = x.shape
        k = c.shape[0]
        if impl == "ref" or d > 4096:
            idx, dist = assign_clusters(
                x, c, impl=impl, compute_dtype=compute_dtype)
            sums, counts = cluster_sums(x, idx, k, impl=impl)
            return idx, dist, sums, counts
        from repro.kernels.lloyd import lloyd_pass_pallas

        bs, bk, _ = _blocks("lloyd", s, k, d, compute_dtype)
        # The fused kernel keeps full-D row blocks resident (lane-padded
        # once); only S and K tile, so x/c are padded exactly once here.
        sp, kp, dp = _round_up(s, bs), _round_up(k, bk), _round_up(d, _LANE)
        xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
        cp = jnp.pad(c, ((0, kp - k), (0, dp - d)))
        idx, dist, sums, counts = lloyd_pass_pallas(
            xp, cp, k_valid=k, s_valid=s, block_s=bs, block_k=bk,
            compute_dtype=compute_dtype, interpret=(impl == "interpret"),
        )
        return idx[:s], dist[:s], sums[:k, :d], counts[:k]


def lloyd_pass(
    x: Array, c: Array, *, impl: str | None = None,
    compute_dtype: str | None = None,
):
    """Fused Lloyd pass: (idx, dist, sums, counts) with ONE read of x.

    Falls back to assign+cluster_sums (two passes) on the ref path or when
    D exceeds the VMEM row-block budget.
    """
    cdt = flags.compute_dtype(compute_dtype)
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _lloyd_pass_jit(x, c, impl=impl, compute_dtype=cdt)
    return _traced_call(
        rec, "kernel.lloyd_pass", {"s": int(x.shape[0]), "k": int(c.shape[0])},
        lambda: _lloyd_pass_jit(x, c, impl=impl, compute_dtype=cdt),
    )


# ---------------------------------------------------------------------------
# autotune probe factories (repro.kernels.autotune times these on a miss in
# REPRO_AUTOTUNE=probe mode; deterministic synthetic data, no host RNG)
# ---------------------------------------------------------------------------


def _probe_data(s: int, d: int, k: int):
    x = (jnp.arange(s * d, dtype=jnp.float32) % 97).reshape(s, d) * 0.1
    c = (jnp.arange(k * d, dtype=jnp.float32) % 89).reshape(k, d) * 0.1
    return x, c


def _probe_assign(s, k, d, dtype, blocks):
    bs, bk, bd = blocks
    sp, kp, dp = _round_up(s, bs), _round_up(k, bk), _round_up(d, bd)
    x, c = _probe_data(sp, dp, kp)
    interpret = jax.default_backend() != "tpu"
    return lambda: assign_pallas(
        x, c, k_valid=k, block_s=bs, block_k=bk, block_d=bd,
        compute_dtype=dtype, interpret=interpret,
    )


def _probe_update(s, k, d, dtype, blocks):
    bs, bk, bd = blocks
    sp, dp = _round_up(s, bs), _round_up(d, bd)
    x, _ = _probe_data(sp, dp, 1)
    idx = (jnp.arange(sp, dtype=jnp.int32) % max(k, 1))
    interpret = jax.default_backend() != "tpu"
    return lambda: cluster_sums_pallas(
        x, idx, k, block_s=bs, block_k=bk, block_d=bd, interpret=interpret,
    )


def _probe_lloyd(s, k, d, dtype, blocks):
    from repro.kernels.lloyd import lloyd_pass_pallas

    bs, bk, _ = blocks
    sp, kp, dp = _round_up(s, bs), _round_up(k, bk), _round_up(d, _LANE)
    x, c = _probe_data(sp, dp, kp)
    interpret = jax.default_backend() != "tpu"
    return lambda: lloyd_pass_pallas(
        x, c, k_valid=k, s_valid=s, block_s=bs, block_k=bk,
        compute_dtype=dtype, interpret=interpret,
    )


autotune.register_probe("assign", _probe_assign)
autotune.register_probe("update", _probe_update)
autotune.register_probe("lloyd", _probe_lloyd)
