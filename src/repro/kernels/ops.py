"""Dispatching wrappers around the Pallas kernels.

``impl`` resolution:
  - "pallas":    compiled TPU kernel (requires a TPU backend).
  - "interpret": Pallas interpret mode — used by the CPU test suite.
  - "ref":       the jnp oracle (what XLA lowers on CPU / in dry-runs).
  - None/"auto": "pallas" on TPU, "ref" elsewhere.

The wrappers own all padding so the kernels can assume hardware-aligned
tiles: S is padded with junk rows (sliced off), D with zero columns (no-op in
dot products), K with +inf-norm centroids (can never win an argmin) /
out-of-range assignments (fall outside every one-hot tile).

Observability: each public wrapper opens a host-side ``kernel.*`` span when a
``repro.obs`` recorder is active AND the call is a real dispatch (arguments
are concrete, not tracers — inside an enclosing jit the wrapper runs at
trace time, where host timing is meaningless). The jitted bodies carry
``jax.named_scope`` labels so the regions survive into HLO metadata and XLA
profiles regardless. Dispatch is asynchronous, so a kernel span measures
dispatch cost unless the recorder was configured with ``sync_kernels=True``
(then the span blocks on the result — true execution time, at the price of a
pipeline bubble).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import ref
from repro.kernels.assign import assign_pallas
from repro.kernels.update import cluster_sums_pallas
from repro.obs import jaxhooks

Array = jax.Array

_LANE = 128
_SUBLANE = 8


def resolve_impl(impl: str | None) -> str:
    if impl in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl


def _round_up(v: int, m: int) -> int:
    return v + (-v) % m


def _traced_call(rec, name: str, attrs: dict, thunk):
    """One host-side kernel span around a dispatch. The span covers dispatch
    only (async) unless the recorder asks for ``sync_kernels`` — then it
    blocks on the result and covers execution."""
    with rec.span(name, **attrs), jaxhooks.trace_annotation(name):
        out = thunk()
        if rec.sync_kernels:
            jax.block_until_ready(out)
    return out


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


@functools.partial(jax.jit, static_argnames=("impl",))
def _assign_clusters_jit(x: Array, c: Array, *, impl: str | None = None) -> tuple[Array, Array]:
    with jaxhooks.named_scope("kernel.assign"):
        impl = resolve_impl(impl)
        if impl == "ref":
            return ref.assign_ref(x, c)
        s, d = x.shape
        k = c.shape[0]
        bs = min(256, _round_up(s, _SUBLANE))
        bk = min(128, _round_up(k, _LANE))
        bd = min(512, _round_up(d, _LANE))
        sp, kp, dp = _round_up(s, bs), _round_up(k, bk), _round_up(d, bd)
        xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
        cp = jnp.pad(c, ((0, kp - k), (0, dp - d)))
        idx, dist = assign_pallas(
            xp, cp, k_valid=k, block_s=bs, block_k=bk, block_d=bd,
            interpret=(impl == "interpret"),
        )
        return idx[:s], dist[:s]


def assign_clusters(x: Array, c: Array, *, impl: str | None = None) -> tuple[Array, Array]:
    """Nearest-centroid assignment: x (s,d), c (k,d) -> (idx (s,), dist (s,))."""
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _assign_clusters_jit(x, c, impl=impl)
    return _traced_call(
        rec, "kernel.assign", {"s": int(x.shape[0]), "k": int(c.shape[0])},
        lambda: _assign_clusters_jit(x, c, impl=impl),
    )


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def _cluster_sums_jit(x: Array, idx: Array, k: int, *, impl: str | None = None) -> tuple[Array, Array]:
    with jaxhooks.named_scope("kernel.update"):
        impl = resolve_impl(impl)
        if impl == "ref":
            return ref.cluster_sums_ref(x, idx, k)
        s, d = x.shape
        bs = min(512, _round_up(s, _SUBLANE))
        bd = min(512, _round_up(d, _LANE))
        sp, dp = _round_up(s, bs), _round_up(d, bd)
        kp = _round_up(k, min(128, _round_up(k, _LANE)))
        # Padding rows get assignment kp (out of range of every tile).
        idxp = jnp.pad(idx.astype(jnp.int32), (0, sp - s), constant_values=kp)
        xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
        sums, counts = cluster_sums_pallas(
            xp, idxp, k, block_s=bs, block_k=min(128, kp), block_d=bd,
            interpret=(impl == "interpret"),
        )
        return sums[:, :d], counts


def cluster_sums(x: Array, idx: Array, k: int, *, impl: str | None = None) -> tuple[Array, Array]:
    """Per-cluster sums (k,d) and counts (k,) from assignments idx (s,)."""
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _cluster_sums_jit(x, idx, k, impl=impl)
    return _traced_call(
        rec, "kernel.update", {"s": int(x.shape[0]), "k": k},
        lambda: _cluster_sums_jit(x, idx, k, impl=impl),
    )


@functools.partial(jax.jit, static_argnames=("impl",))
def _mssc_objective_jit(x: Array, c: Array, *, impl: str | None = None) -> Array:
    with jaxhooks.named_scope("kernel.objective"):
        _, dist = assign_clusters(x, c, impl=impl)
        return jnp.sum(dist)


def mssc_objective(x: Array, c: Array, *, impl: str | None = None) -> Array:
    """Equation (1): sum of squared distances to nearest centroids."""
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _mssc_objective_jit(x, c, impl=impl)
    return _traced_call(
        rec, "kernel.objective", {"s": int(x.shape[0]), "k": int(c.shape[0])},
        lambda: _mssc_objective_jit(x, c, impl=impl),
    )


@functools.partial(jax.jit, static_argnames=("impl",))
def _lloyd_pass_jit(x: Array, c: Array, *, impl: str | None = None):
    with jaxhooks.named_scope("kernel.lloyd_pass"):
        impl = resolve_impl(impl)
        s, d = x.shape
        k = c.shape[0]
        if impl == "ref" or d > 4096:
            idx, dist = assign_clusters(x, c, impl=impl)
            sums, counts = cluster_sums(x, idx, k, impl=impl)
            return idx, dist, sums, counts
        from repro.kernels.lloyd import lloyd_pass_pallas

        bs = min(256, _round_up(s, _SUBLANE))
        bk = min(128, _round_up(k, _LANE))
        dp = _round_up(d, _LANE)
        sp, kp = _round_up(s, bs), _round_up(k, bk)
        xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
        cp = jnp.pad(c, ((0, kp - k), (0, dp - d)))
        idx, dist, sums, counts = lloyd_pass_pallas(
            xp, cp, k_valid=k, s_valid=s, block_s=bs, block_k=bk,
            interpret=(impl == "interpret"),
        )
        return idx[:s], dist[:s], sums[:k, :d], counts[:k]


def lloyd_pass(x: Array, c: Array, *, impl: str | None = None):
    """Fused Lloyd pass: (idx, dist, sums, counts) with ONE read of x.

    Falls back to assign+cluster_sums (two passes) on the ref path or when
    D exceeds the VMEM row-block budget.
    """
    rec = obs.get_recorder()
    if rec is None or not _is_concrete(x):
        return _lloyd_pass_jit(x, c, impl=impl)
    return _traced_call(
        rec, "kernel.lloyd_pass", {"s": int(x.shape[0]), "k": int(c.shape[0])},
        lambda: _lloyd_pass_jit(x, c, impl=impl),
    )
