"""Dispatching wrappers around the Pallas kernels.

``impl`` resolution:
  - "pallas":    compiled TPU kernel (requires a TPU backend).
  - "interpret": Pallas interpret mode — used by the CPU test suite.
  - "ref":       the jnp oracle (what XLA lowers on CPU / in dry-runs).
  - None/"auto": "pallas" on TPU, "ref" elsewhere.

The wrappers own all padding so the kernels can assume hardware-aligned
tiles: S is padded with junk rows (sliced off), D with zero columns (no-op in
dot products), K with +inf-norm centroids (can never win an argmin) /
out-of-range assignments (fall outside every one-hot tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.assign import assign_pallas
from repro.kernels.update import cluster_sums_pallas

Array = jax.Array

_LANE = 128
_SUBLANE = 8


def resolve_impl(impl: str | None) -> str:
    if impl in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl


def _round_up(v: int, m: int) -> int:
    return v + (-v) % m


@functools.partial(jax.jit, static_argnames=("impl",))
def assign_clusters(x: Array, c: Array, *, impl: str | None = None) -> tuple[Array, Array]:
    """Nearest-centroid assignment: x (s,d), c (k,d) -> (idx (s,), dist (s,))."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.assign_ref(x, c)
    s, d = x.shape
    k = c.shape[0]
    bs = min(256, _round_up(s, _SUBLANE))
    bk = min(128, _round_up(k, _LANE))
    bd = min(512, _round_up(d, _LANE))
    sp, kp, dp = _round_up(s, bs), _round_up(k, bk), _round_up(d, bd)
    xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
    cp = jnp.pad(c, ((0, kp - k), (0, dp - d)))
    idx, dist = assign_pallas(
        xp, cp, k_valid=k, block_s=bs, block_k=bk, block_d=bd,
        interpret=(impl == "interpret"),
    )
    return idx[:s], dist[:s]


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def cluster_sums(x: Array, idx: Array, k: int, *, impl: str | None = None) -> tuple[Array, Array]:
    """Per-cluster sums (k,d) and counts (k,) from assignments idx (s,)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.cluster_sums_ref(x, idx, k)
    s, d = x.shape
    bs = min(512, _round_up(s, _SUBLANE))
    bd = min(512, _round_up(d, _LANE))
    sp, dp = _round_up(s, bs), _round_up(d, bd)
    kp = _round_up(k, min(128, _round_up(k, _LANE)))
    # Padding rows get assignment kp (out of range of every tile).
    idxp = jnp.pad(idx.astype(jnp.int32), (0, sp - s), constant_values=kp)
    xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
    sums, counts = cluster_sums_pallas(
        xp, idxp, k, block_s=bs, block_k=min(128, kp), block_d=bd,
        interpret=(impl == "interpret"),
    )
    return sums[:, :d], counts


@functools.partial(jax.jit, static_argnames=("impl",))
def mssc_objective(x: Array, c: Array, *, impl: str | None = None) -> Array:
    """Equation (1): sum of squared distances to nearest centroids."""
    _, dist = assign_clusters(x, c, impl=impl)
    return jnp.sum(dist)


@functools.partial(jax.jit, static_argnames=("impl",))
def lloyd_pass(x: Array, c: Array, *, impl: str | None = None):
    """Fused Lloyd pass: (idx, dist, sums, counts) with ONE read of x.

    Falls back to assign+cluster_sums (two passes) on the ref path or when
    D exceeds the VMEM row-block budget.
    """
    impl = resolve_impl(impl)
    s, d = x.shape
    k = c.shape[0]
    if impl == "ref" or d > 4096:
        idx, dist = assign_clusters(x, c, impl=impl)
        sums, counts = cluster_sums(x, idx, k, impl=impl)
        return idx, dist, sums, counts
    from repro.kernels.lloyd import lloyd_pass_pallas

    bs = min(256, _round_up(s, _SUBLANE))
    bk = min(128, _round_up(k, _LANE))
    dp = _round_up(d, _LANE)
    sp, kp = _round_up(s, bs), _round_up(k, bk)
    xp = jnp.pad(x, ((0, sp - s), (0, dp - d)))
    cp = jnp.pad(c, ((0, kp - k), (0, dp - d)))
    idx, dist, sums, counts = lloyd_pass_pallas(
        xp, cp, k_valid=k, s_valid=s, block_s=bs, block_k=bk,
        interpret=(impl == "interpret"),
    )
    return idx[:s], dist[:s], sums[:k, :d], counts[:k]
