from repro.kernels.ops import assign_clusters, cluster_sums, lloyd_pass, mssc_objective

__all__ = ["assign_clusters", "cluster_sums", "lloyd_pass", "mssc_objective"]
