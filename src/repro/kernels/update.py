"""Cluster-sum Pallas TPU kernel: one-hot(assignment)^T @ X on the MXU.

The centroid-update half of a Lloyd iteration needs, per cluster j,
``sum_{i: a_i = j} x_i`` and ``|{i: a_i = j}|``. A scatter-add is the GPU
idiom; TPUs have no fast scatter, but the same quantity is a matmul against
the one-hot assignment matrix — which the MXU eats. We build the one-hot
tile on the fly in VMEM (an iota==idx compare), so the (s, k) one-hot matrix
never exists in HBM either.

Grid: (k/bk, d/bd, s/bs), s innermost, so each (bk, bd) output block stays
resident in VMEM while all point tiles stream through it. Counts are
accumulated only on the d==0 slice of the grid (they do not depend on d).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 512
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_D = 256


def _update_kernel(
    idx_ref,    # (bs, 1)  int32 assignments
    x_ref,      # (bs, bd) f32 point tile
    sums_ref,   # out (bk, bd) f32
    counts_ref, # out (bk, 1)  f32
    *,
    bk: int,
):
    ki = pl.program_id(0)
    di = pl.program_id(1)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)

        @pl.when(di == 0)
        def _init_counts():
            counts_ref[...] = jnp.zeros_like(counts_ref)

    ids = idx_ref[...]  # (bs, 1)
    # Global centroid ids covered by this k-tile, as a (1, bk) row.
    kk = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    onehot = (ids == kk).astype(jnp.float32)  # (bs, bk)

    # (bk, bs) x (bs, bd) on the MXU.
    sums_ref[...] += jax.lax.dot_general(
        onehot,
        x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(di == 0)
    def _counts():
        counts_ref[...] += jnp.sum(onehot, axis=0)[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_s", "block_k", "block_d", "interpret"),
)
def cluster_sums_pallas(
    x: jax.Array,
    idx: jax.Array,
    k: int,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    block_k: int = DEFAULT_BLOCK_K,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-cluster sums/counts. x: (s, d) padded, idx: (s,) int32 in [0, k_pad).

    Padding rows must carry an out-of-range assignment (ops.py uses ``k_pad``)
    so they fall outside every one-hot tile and contribute nothing.
    """
    s, d = x.shape
    assert idx.shape == (s,), (idx.shape, s)
    bs, bd = min(block_s, s), min(block_d, d)
    # K pads up to the block (kp >= bk always), unlike s/d where the block
    # shrinks to the data: out-of-range padding assignments need kp > k.
    bk = block_k
    kp = k + (-k) % bk
    assert s % bs == 0 and d % bd == 0 and kp % bk == 0, (s, d, kp, bs, bd, bk)

    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel, bk=bk),
        grid=(kp // bk, d // bd, s // bs),
        in_specs=[
            pl.BlockSpec((bs, 1), lambda ki, di, si: (si, 0)),
            pl.BlockSpec((bs, bd), lambda ki, di, si: (si, di)),
        ],
        out_specs=[
            pl.BlockSpec((bk, bd), lambda ki, di, si: (ki, di)),
            pl.BlockSpec((bk, 1), lambda ki, di, si: (ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, d), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(idx[:, None].astype(jnp.int32), x.astype(jnp.float32))
    return sums[:k], counts[:k, 0]
