"""Tile-size autotuner for the Pallas kernels.

The wrappers in ``repro.kernels.ops`` pick ``(block_s, block_k, block_d)``
with fixed heuristics (``min(256, ...)``-style). Those defaults are sane on
one TPU generation at the paper's shapes, but the VMEM budget, MXU shape and
grid overheads all move with backend and problem size — on the "fast as the
hardware allows" north star the tile choice is a measurable multiplier on the
assign/update hot loop.

This module closes the loop:

  * ``candidates()`` enumerates hardware-aligned tile triples whose working
    set fits the static VMEM budget (the same budget the PK002 static
    analysis check enforces on kernel sites);
  * ``probe()`` times each candidate on a short synthetic run of the real
    kernel (compile excluded — one warmup call, then a timed median) and
    returns the winner;
  * winners persist in a JSON cache keyed by ``(backend, kernel,
    shape-bucket, dtype)`` so one probe serves every subsequent process.

``ops.py`` consults ``lookup()`` at trace time — a pure in-memory dict read
after the first file load — and falls back to its heuristics whenever the
feature is off (``REPRO_AUTOTUNE`` unset), the cache misses, or probing is
not allowed. Shape *buckets* (next power of two per dim) keep the cache
small and make one probe cover the whole jit-retrace neighbourhood.

Cache format (docs/performance.md §Autotuner)::

    {"version": 1,
     "entries": {"cpu/assign/s4096/k128/d256/f32":
                 {"blocks": [256, 128, 256], "us": 812.4}}}
"""
from __future__ import annotations

import json
import os
import statistics
import threading
import time
from typing import Callable, Iterable, Optional

from repro import flags

_LANE = 128
_SUBLANE = {"f32": 8, "bf16": 16}

# Conservative per-core VMEM budget for one kernel's working set. Real cores
# have ~16 MiB; Pallas double-buffers grid inputs, so target half of it.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

CACHE_VERSION = 1

_lock = threading.Lock()
_mem_cache: dict[str, dict] | None = None
_mem_cache_path: str | None = None


def _round_up(v: int, m: int) -> int:
    return v + (-v) % m


def _bucket(v: int) -> int:
    """Next power of two >= v (shape bucket — one probe per neighbourhood)."""
    b = 1
    while b < v:
        b *= 2
    return b


def _bytes(dtype: str) -> int:
    return 2 if dtype == "bf16" else 4


def vmem_bytes(kernel: str, bs: int, bk: int, bd: int, *,
               k_total: int | None = None, dtype: str = "f32") -> int:
    """Static VMEM working-set estimate for one grid step of ``kernel``.

    Mirrors the BlockSpecs/scratch in assign.py / update.py / lloyd.py; kept
    deliberately simple (inputs + outputs + scratch, no pipelining factor —
    the halved ``VMEM_BUDGET_BYTES`` accounts for double buffering).
    """
    eb = _bytes(dtype)
    if kernel == "assign":
        # xn (bs,1) + cn (1,bk) + x (bs,bd) + c (bk,bd) tiles, f32 acc
        # (bs,bk) scratch, (bs,1) best/bidx scratch, (bs,1) x2 outputs.
        return (
            bs * 4 + bk * 4 + bs * bd * eb + bk * bd * eb
            + bs * bk * 4 + bs * 4 + bs * 4 + bs * 8
        )
    if kernel == "update":
        # idx (bs,1) + x (bs,bd) in, sums (bk,bd) + counts (bk,1) resident.
        return bs * 4 + bs * bd * eb + bk * bd * 4 + bk * 4
    if kernel == "lloyd":
        # full-D row blocks: x (bs,D) + c (bk,D) + resident sums (K,D).
        kt = k_total if k_total is not None else bk
        return (
            bk * 4 + bs * bd * eb + bk * bd * eb + kt * bd * 4 + kt * 4
            + bs * 8 + bs * 8
        )
    raise ValueError(f"unknown kernel {kernel!r}")


def candidates(
    kernel: str, s: int, k: int, d: int, *, dtype: str = "f32",
    budget: int = VMEM_BUDGET_BYTES,
) -> list[tuple[int, int, int]]:
    """Hardware-aligned (block_s, block_k, block_d) triples under ``budget``.

    Every block divides the padded problem (ops.py pads to the chosen block),
    sublane-aligns block_s (8 for f32, 16 for bf16) and lane-aligns
    block_k/block_d (128).
    """
    sub = _SUBLANE[dtype]
    s_opts = [o for o in (sub, 64, 128, 256, 512, 1024) if o >= sub]
    k_opts = (128, 256)
    d_opts = (128, 256, 512, 1024)
    sp, kp, dp = _round_up(s, sub), _round_up(k, _LANE), _round_up(d, _LANE)
    out = []
    for bs in s_opts:
        if bs > sp and bs > sub:  # block bigger than the padded data
            continue
        for bk in k_opts:
            if bk > kp and bk != _LANE:
                continue
            for bd in d_opts:
                if bd > dp and bd != _LANE:
                    continue
                kt = _round_up(k, bk) if kernel == "lloyd" else None
                if vmem_bytes(kernel, bs, bk, bd, k_total=kt,
                              dtype=dtype) <= budget:
                    out.append((bs, bk, bd))
    return out


def cache_key(kernel: str, s: int, k: int, d: int, *, dtype: str = "f32",
              backend: str | None = None) -> str:
    if backend is None:
        import jax

        backend = jax.default_backend()
    return (f"{backend}/{kernel}/s{_bucket(s)}/k{_bucket(k)}"
            f"/d{_bucket(d)}/{dtype}")


# ---------------------------------------------------------------------------
# cache persistence
# ---------------------------------------------------------------------------


def _load(path: str) -> dict[str, dict]:
    global _mem_cache, _mem_cache_path
    with _lock:
        if _mem_cache is not None and _mem_cache_path == path:
            return _mem_cache
        entries: dict[str, dict] = {}
        try:
            with open(path, encoding="utf-8") as fh:
                raw = json.load(fh)
            if isinstance(raw, dict) and raw.get("version") == CACHE_VERSION:
                entries = dict(raw.get("entries", {}))
        except (OSError, ValueError):
            entries = {}  # missing or corrupt cache == empty cache
        _mem_cache, _mem_cache_path = entries, path
        return entries


def _store(path: str, key: str, blocks: tuple[int, int, int],
           us: float) -> None:
    with _lock:
        entries = dict(_mem_cache or {})
        entries[key] = {"blocks": list(blocks), "us": round(us, 1)}
        _set_mem(path, entries)
        try:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w", encoding="utf-8") as fh:
                json.dump({"version": CACHE_VERSION, "entries": entries},
                          fh, indent=1, sort_keys=True)
                fh.write("\n")
            os.replace(tmp, path)
        except OSError:
            pass  # a read-only cache dir degrades to per-process memory


def _set_mem(path: str, entries: dict[str, dict]) -> None:
    global _mem_cache, _mem_cache_path
    _mem_cache, _mem_cache_path = entries, path


def invalidate_memory_cache() -> None:
    """Forget the in-process cache copy (tests / cache-path changes)."""
    global _mem_cache, _mem_cache_path
    with _lock:
        _mem_cache = None
        _mem_cache_path = None


# ---------------------------------------------------------------------------
# probing
# ---------------------------------------------------------------------------


def probe(
    make_call: Callable[[tuple[int, int, int]], Callable[[], object]],
    cands: Iterable[tuple[int, int, int]],
    *,
    reps: int = 3,
) -> tuple[tuple[int, int, int], float]:
    """Time ``make_call(blocks)()`` for each candidate; return (winner, us).

    One un-timed warmup per candidate swallows compilation; the score is the
    median of ``reps`` timed calls. Candidates that fail to build/run (e.g.
    an over-budget tile the estimate missed) are skipped.
    """
    import jax

    best: Optional[tuple[int, int, int]] = None
    best_us = float("inf")
    for blocks in cands:
        try:
            call = make_call(blocks)
            jax.block_until_ready(call())  # warmup / compile
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(call())
                ts.append((time.perf_counter() - t0) * 1e6)
            us = statistics.median(ts)
        except Exception:  # noqa: BLE001 — a broken tile is just not a winner
            continue
        if us < best_us:
            best, best_us = blocks, us
    if best is None:
        raise RuntimeError("no autotune candidate succeeded")
    return best, best_us


# Per-kernel probe-call factories are registered by ops.py (it owns the
# padded call convention); keys are kernel names.
_PROBE_FACTORIES: dict[str, Callable] = {}


def register_probe(kernel: str, factory: Callable) -> None:
    """factory(s, k, d, dtype, blocks) -> zero-arg timed callable."""
    _PROBE_FACTORIES[kernel] = factory


def lookup(
    kernel: str, s: int, k: int, d: int, *, dtype: str = "f32",
    backend: str | None = None,
) -> Optional[tuple[int, int, int]]:
    """Tuned (block_s, block_k, block_d) for this shape bucket, or None.

    Honors ``REPRO_AUTOTUNE``: 'off' -> always None (heuristics), 'on' ->
    cache consult only, 'probe' -> cache consult, then time candidates on a
    miss and persist the winner. Pure Python — safe to call at jit trace
    time (the probe path executes *compiled* kernels, which is legal during
    tracing, just slow the first time).
    """
    mode = flags.autotune_mode()
    if mode == "off":
        return None
    path = flags.autotune_cache_path()
    key = cache_key(kernel, s, k, d, dtype=dtype, backend=backend)
    hit = _load(path).get(key)
    if hit is not None:
        blocks = hit.get("blocks")
        if (isinstance(blocks, (list, tuple)) and len(blocks) == 3
                and all(isinstance(b, int) and b > 0 for b in blocks)):
            return tuple(blocks)  # type: ignore[return-value]
    if mode != "probe":
        return None
    factory = _PROBE_FACTORIES.get(kernel)
    if factory is None:
        return None
    # Probe at the bucketed shape so the persisted winner matches every
    # shape that maps to this key, not just the first one seen.
    sb, kb, db = _bucket(s), _bucket(k), _bucket(d)
    cands = candidates(kernel, sb, kb, db, dtype=dtype)
    if not cands:
        return None
    try:
        blocks, us = probe(
            lambda b: factory(sb, kb, db, dtype, b), cands)
    except RuntimeError:
        return None
    _store(path, key, blocks, us)
    return blocks
