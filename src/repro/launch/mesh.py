"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax call).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips/pod) single-pod or 2x16x16 multi-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / CPU dry-runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (max(1, n // 2), min(2, n)) if n > 1 else (1, 1)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
