"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax call).

``make_host_mesh`` is the elastic entry point: ``exclude`` drops lost
devices (by ``Device.id``) and rebuilds the largest usable mesh over the
survivors — the degraded-mesh recovery path in ``repro.launch.elastic``.
"""
from __future__ import annotations

import math

import jax

try:  # jax >= 0.5 annotates axes; older versions have no AxisType at all
    from jax.sharding import AxisType

    _AXIS_TYPES = True
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None
    _AXIS_TYPES = False


def _make_mesh(shape, axes, devices=None):
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _AXIS_TYPES:
        kw["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips/pod) single-pod or 2x16x16 multi-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model"), *, exclude=()):
    """Small mesh over whatever devices exist (tests / CPU dry-runs).

    ``exclude`` names lost devices by ``Device.id``; the mesh is rebuilt
    over the survivors. With no explicit ``shape`` the survivors split as
    (n//2, 2) when n is even, else (n, 1) — worker groups (the ``data``
    axis) are preserved over inner parallelism so a degraded mesh keeps
    as many competitive searchers as possible.
    """
    lost = frozenset(exclude)
    devs = [d for d in jax.devices() if d.id not in lost]
    if not devs:
        raise RuntimeError(
            f"no devices survive exclusion of {sorted(lost)}"
        )
    n = len(devs)
    if shape is None:
        model = 2 if n > 1 and n % 2 == 0 else 1
        shape = (n // model, model)
    need = math.prod(shape)
    if need > n:
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {need} devices, "
            f"only {n} survive"
        )
    return _make_mesh(shape, axes, devices=devs[:need])
