"""Batched serving driver (continuous batching at smoke scale).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --requests 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.models import model as M
from repro.serving import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL trace to PATH (read with "
                         "`python -m repro.obs summarize PATH`)")
    args = ap.parse_args(argv)

    if args.trace:
        obs.configure(jsonl=args.trace)
    try:
        cfg = get_config(args.arch, smoke=True)
        params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
        rng = np.random.default_rng(args.seed)
        reqs = [
            Request(rid=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_tokens=args.max_tokens)
            for i in range(args.requests)
        ]
        eng = ServeEngine(cfg, params, slots=args.slots, max_len=128)
        t0 = time.time()
        done = eng.run(reqs)
        dt = time.time() - t0
        toks = sum(len(r.out) for r in reqs)
        lats = [r.latency_s for r in done if r.latency_s is not None]
        print(json.dumps({
            "requests": len(reqs), "completed": sum(r.done for r in reqs),
            "tokens": toks, "wall_s": round(dt, 2),
            "tok_per_s": round(toks / max(dt, 1e-9), 1),
            "latency_mean_s": round(sum(lats) / len(lats), 4) if lats else None,
            "latency_max_s": round(max(lats), 4) if lats else None,
        }, indent=1))
        return 0
    finally:
        obs.shutdown()


if __name__ == "__main__":
    raise SystemExit(main())
