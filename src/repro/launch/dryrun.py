import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: the full
parameter/optimizer/cache pytrees exist only as ShapeDtypeStructs; jit
lowering + GSPMD partitioning + backend compilation run for the production
meshes (16x16 single-pod, 2x16x16 multi-pod). Per cell we record:

  * memory_analysis()  — per-device argument/output/temp bytes (proves fit);
  * cost_analysis()    — HLO FLOPs / bytes accessed for the roofline;
  * collective bytes   — parsed from the post-SPMD HLO text: summed operand
    bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute (per-device program => per-device bytes).

Artifacts: one JSON per cell under --out (default experiments/dryrun).
benchmarks/roofline.py consumes them. Also supports the paper's own
HPClust production configs (arch "hpclust-prod").

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.configs import ARCHS, get_config
from repro.distributed import sharding as shd
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.models import model as M

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b"
)
SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        if "-done" in line:  # the -start op already carried the operands
            continue
        kind = m.group(1)
        # shapes on the line: first (lhs result), rest are operand types.
        shapes = SHAPE_RE.findall(line)
        if len(shapes) < 2:
            continue
        rhs = line.split("=", 1)[1]
        operands = SHAPE_RE.findall(rhs.split("(", 1)[1]) if "(" in rhs else []
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in operands)
        out[kind] = out.get(kind, 0) + nbytes
    return out


def _cost_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on newer jax but a
    list of per-program dicts on older releases (e.g. 0.4.x); normalise
    to one flat dict either way."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {"available": False}
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    d = {"available": True}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            d[k] = int(v)
    return d


def _analytic_bytes(tree, shardings, mesh) -> int:
    """Per-device bytes of a pytree given its shardings (exact, analytic)."""
    total = 0
    leaves, treedef = jax.tree.flatten(tree)
    shard_leaves = jax.tree.flatten(shardings)[0]
    for leaf, sh in zip(leaves, shard_leaves):
        n = 1
        for d in leaf.shape:
            n *= d
        denom = 1
        if isinstance(sh, NamedSharding):
            for ax in sh.spec:
                if ax is None:
                    continue
                for a in (ax,) if isinstance(ax, str) else ax:
                    denom *= mesh.shape[a]
        total += n * jnp.dtype(leaf.dtype).itemsize // max(denom, 1)
    return total


def build_cell(arch: str, shape: str, mesh, cfg=None):
    """Returns (jitted fn, example abstract args tuple, static meta)."""
    cfg = cfg if cfg is not None else get_config(arch)
    meta = S.SHAPES[shape]
    dp = shd.dp_axes(mesh)
    # Pin the residual stream to DP sharding at every block boundary: the
    # scanned carry/residual stacks otherwise default to replicated.
    M.set_activation_spec(P(dp, None, None) if meta["global_batch"] > 1 else None)
    M.set_cache_spec_fn(None)
    p_shard = shd.param_shardings(cfg, mesh)
    specs = S.input_specs(cfg, shape)
    param_structs = M.param_shapes(cfg)

    def batch_shardings(batch):
        out = {}
        for k, v in batch.items():
            extra = (None,) * (len(v.shape) - 1)
            out[k] = NamedSharding(mesh, P(dp, *extra))
        return out

    if meta["kind"] == "train":
        step = S.make_train_step(cfg)
        opt = step.optimizer
        opt_structs = S.opt_state_structs(cfg, opt)
        pspecs = M.param_specs(cfg, shd.logical_rules(mesh))
        pspecs = {k: shd.dedupe_spec(s) for k, s in pspecs.items()}
        o_specs = opt.state_specs(pspecs)
        o_shard = jax.tree.map(
            lambda s, struct: NamedSharding(
                mesh,
                shd._drop_indivisible(shd.dedupe_spec(s), struct.shape, mesh),
            ),
            o_specs, opt_structs,
            is_leaf=lambda x: isinstance(x, P),
        )
        b_shard = batch_shardings(specs["batch"])
        # The sharding pytrees closed over here are unhashable, so a cache
        # key cannot be formed.
        # analysis: allow JH003 — one lowering per cell
        fn = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (param_structs, opt_structs, specs["batch"])
        arg_sharding_trees = (p_shard, o_shard, b_shard)
    elif meta["kind"] == "prefill":
        step = S.make_prefill_step(cfg)
        b_shard = batch_shardings(specs["batch"])
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        model_size = mesh.shape["model"]

        def cache_spec(shape, _dp=dp, _dps=dp_size, _ms=model_size):
            # per-layer cache leaves inside the scan: (B, S, ...) — batch
            # over DP, trailing feature dim over model when divisible.
            if len(shape) < 2:
                return None
            axes = [None] * len(shape)
            if shape[0] % _dps == 0:
                axes[0] = _dp
            if len(shape) >= 3 and shape[-1] % _ms == 0 and shape[-1] >= 2 * _ms:
                axes[-1] = "model"
            return P(*axes)

        M.set_cache_spec_fn(cache_spec)
        # analysis: allow JH003 — one lowering per cell (see above)
        fn = jax.jit(step, in_shardings=(p_shard, b_shard))
        args = (param_structs, specs["batch"])
        arg_sharding_trees = (p_shard, b_shard)
    else:
        step = S.make_decode_step(cfg)
        cfg_local = cfg
        seq_par = meta["global_batch"] == 1
        c_shard = shd.cache_sharding(cfg_local, mesh, specs["caches"],
                                     seq_parallel=seq_par)
        t_shard = NamedSharding(mesh, P(dp, None)) if meta["global_batch"] > 1 \
            else NamedSharding(mesh, P())
        # analysis: allow JH003 — one lowering per cell (see above)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, t_shard, NamedSharding(mesh, P()), c_shard),
            donate_argnums=(3,),
        )
        args = (param_structs, specs["tokens"], specs["pos"], specs["caches"])
        arg_sharding_trees = (p_shard, t_shard, None, c_shard)

    return cfg, fn, args, arg_sharding_trees


def _compile_cost(arch: str, shape: str, mesh, cfg_v) -> dict:
    """Compile a (small, unrolled) variant; return cost + collectives."""
    _, fn, args_, _sh = build_cell(arch, shape, mesh, cfg=cfg_v)
    with mesh:
        compiled = fn.lower(*args_).compile()
    ca = _cost_dict(compiled)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(compiled.as_text()),
    }


def calibrate_cell(arch: str, shape: str, *, multi_pod: bool) -> dict:
    """Affine extrapolation of per-segment (and per-microbatch) costs.

    XLA cost analysis counts while bodies ONCE regardless of trip count, so
    scanned models under-report. We compile small *unrolled* variants
    (flat HLO, counted exactly): a base with every segment at n=1 (and
    grad_accum=1), one variant per segment at n=2, and — for training with
    accumulation — an accum=2 variant. FLOPs/bytes/collectives are affine in
    each count, so:

        cost(N_1..N_k, A) = base + sum_s (N_s-1) * Delta_s + (A-1) * Delta_a
    """
    import dataclasses as _dc

    from repro.models import model as _m

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = _m.build_plan(cfg)
    is_train = S.SHAPES[shape]["kind"] == "train"

    def variant(counts, accum=1):
        v = _dc.replace(cfg, plan_override=tuple(counts), unroll=True,
                        grad_accum=accum if is_train else cfg.grad_accum)
        return _compile_cost(arch, shape, mesh, v)

    base_counts = [(s.name, 1) for s in plan]
    base = variant(base_counts)

    def combine(tot, var, scale):
        tot["flops"] += (var["flops"] - base["flops"]) * scale
        tot["bytes"] += (var["bytes"] - base["bytes"]) * scale
        for k in set(var["collectives"]) | set(base["collectives"]):
            d = var["collectives"].get(k, 0) - base["collectives"].get(k, 0)
            tot["collectives"][k] = tot["collectives"].get(k, 0) + d * scale

    total = {
        "flops": base["flops"], "bytes": base["bytes"],
        "collectives": dict(base["collectives"]),
    }
    per_seg = {}
    for s in plan:
        if s.n <= 1:
            continue
        counts = [(x.name, 2 if x.name == s.name else 1) for x in plan]
        var = variant(counts)
        per_seg[s.name] = {"flops": var["flops"] - base["flops"],
                           "bytes": var["bytes"] - base["bytes"]}
        combine(total, var, s.n - 1)
    if is_train and cfg.grad_accum > 1:
        var_a = variant(base_counts, accum=2)
        per_seg["_accum"] = {"flops": var_a["flops"] - base["flops"]}
        combine(total, var_a, cfg.grad_accum - 1)
    total["collectives"] = {k: max(0, int(v)) for k, v in total["collectives"].items()}
    total["collective_bytes_total"] = int(sum(total["collectives"].values()))
    total["per_segment"] = per_seg
    total["plan"] = [(s.name, s.n) for s in plan]
    return total


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: Path,
             hlo_dir: Path | None = None, calibrate: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, fn, args, arg_shardings = build_cell(arch, shape, mesh)
    rec: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "chips": mesh.size, "status": "ok",
    }
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
    rec["lower_s"] = round(t_lower - t0, 2)
    rec["compile_s"] = round(t_compile - t_lower, 2)
    ca = _cost_dict(compiled)
    rec["cost"] = {
        "flops": float(ca.get("flops", -1.0)),
        "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        "transcendentals": float(ca.get("transcendentals", -1.0)),
    }
    rec["memory_analysis"] = _mem_dict(compiled)
    # analytic per-device sizes for the big operands
    mesh_obj = mesh
    rec["arg_bytes_per_device"] = int(
        sum(
            _analytic_bytes(a, s if s is not None else jax.tree.map(
                lambda _: NamedSharding(mesh_obj, P()), a), mesh_obj)
            for a, s in zip(args, arg_shardings)
        )
    )
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["collective_bytes_total"] = int(sum(rec["collectives"].values()))
    rec["n_params"] = int(
        sum(int(jnp.prod(jnp.array(v.shape))) for v in M.param_shapes(cfg).values())
    )
    if hlo_dir is not None:
        hlo_dir.mkdir(parents=True, exist_ok=True)
        (hlo_dir / f"{arch}__{shape}__{mesh_name}.hlo.txt").write_text(hlo)
    # Roofline calibration is a single-pod deliverable (the multi-pod pass
    # only proves the `pod` axis shards); skip the extra compiles there.
    if calibrate and not multi_pod:
        try:
            rec["cost_calibrated"] = calibrate_cell(arch, shape, multi_pod=multi_pod)
        except Exception as e:  # noqa: BLE001
            rec["cost_calibrated"] = {"error": repr(e)}
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
    path.write_text(json.dumps(rec, indent=1))
    return rec


@functools.lru_cache(maxsize=None)
def _jit_hpclust_runner(mesh, cfg, pod_axis):
    """One compiled SPMD runner per (mesh, cfg, pod_axis) cell — both the
    faithful and optimized hpclust-prod cells re-lower through this cache."""
    from repro.core.sharded import build_sharded_runner

    fn, in_sh, out_sh = build_sharded_runner(mesh, cfg, pod_axis=pod_axis)
    return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)


def run_hpclust_cell(*, multi_pod: bool, out_dir: Path,
                     optimized: bool = False) -> dict:
    """Dry-run the paper's own workload on the production mesh.

    optimized=False -> paper-faithful: f32 reservoir, hybrid (T1/T2).
    optimized=True  -> beyond-paper: bf16 reservoir (distance math still
    accumulates in f32), hierarchical hybrid2 on multi-pod, one fused stats
    pass per round (kmeans_iters trimmed to the observed convergence
    budget). Recorded separately per the assignment.
    """
    from repro.core.sharded import state_shapes
    from repro.core.strategies import HPClustConfig

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    workers = mesh.size // mesh.shape["model"]
    strategy = ("hybrid2" if multi_pod else "hybrid")
    cfg = HPClustConfig(
        k=25, sample_size=1 << 17, workers=workers, rounds=8,
        strategy=strategy,
        groups=2 if multi_pod else 1, fixed_schedule=True,
        kmeans_iters=24 if optimized else 32, impl="ref",
    )
    d, m_shard = 768, 1 << 20  # CORD-19-like dims; 1M-row reservoir/worker
    jfn = _jit_hpclust_runner(mesh, cfg, "pod" if multi_pod else None)
    state = state_shapes(cfg, d)
    res_dtype = jnp.bfloat16 if optimized else jnp.float32
    reservoir = jax.ShapeDtypeStruct((workers, m_shard, d), res_dtype)
    t0 = time.time()
    with mesh:
        lowered = jfn.lower(state, reservoir)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    ca = _cost_dict(compiled)
    name = "hpclust-prod-opt" if optimized else "hpclust-prod"
    rec = {
        "arch": name, "shape": f"k25_s131072_w{workers}",
        "mesh": mesh_name, "chips": mesh.size, "status": "ok",
        "strategy": strategy, "reservoir_dtype": str(res_dtype.__name__),
        "lower_compile_s": round(time.time() - t0, 2),
        "cost": {"flops": float(ca.get("flops", -1.0)),
                 "bytes_accessed": float(ca.get("bytes accessed", -1.0))},
        "memory_analysis": _mem_dict(compiled),
        "collectives": collective_bytes(hlo),
    }
    rec["collective_bytes_total"] = int(sum(rec["collectives"].values()))
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{name}__{mesh_name}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'hpclust-prod'")
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL trace to PATH (read with "
                         "`python -m repro.obs summarize PATH`)")
    args = ap.parse_args(argv)

    if args.trace:
        obs.configure(jsonl=args.trace)
    try:
        return _run_cells(args)
    finally:
        obs.shutdown()


def _run_cells(args):
    out_dir = Path(args.out)
    hlo_dir = Path("experiments/hlo") if args.dump_hlo else None
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(S.SHAPES) if args.shape is None else [args.shape]
    for arch in archs:
        if arch == "hpclust-prod":
            for mp in meshes:
                cells.append(("hpclust-prod", None, mp))
            continue
        cfg = get_config(arch)
        for shape in shapes:
            if not S.cell_is_applicable(cfg, shape):
                print(f"SKIP {arch} x {shape}: long-context N/A "
                      f"(full attention; DESIGN.md SS5)")
                continue
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        name = f"{arch} x {shape or '-'} x {'multi' if mp else 'single'}"
        try:
            with obs.span("dryrun.cell", arch=arch, shape=shape,
                          mesh="multi" if mp else "single"):
                if arch == "hpclust-prod":
                    rec = run_hpclust_cell(multi_pod=mp, out_dir=out_dir)
                    run_hpclust_cell(multi_pod=mp, out_dir=out_dir,
                                     optimized=True)
                elif arch == "hpclust-prod-opt":
                    rec = run_hpclust_cell(multi_pod=mp, out_dir=out_dir,
                                           optimized=True)
                else:
                    rec = run_cell(arch, shape, multi_pod=mp, out_dir=out_dir,
                                   hlo_dir=hlo_dir)
            obs.inc("dryrun.cells_ok")
            print(f"OK   {name}: flops={rec['cost']['flops']:.3e} "
                  f"coll={rec['collective_bytes_total']:.3e}B "
                  f"compile={rec.get('compile_s', rec.get('lower_compile_s'))}s",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            failures += 1
            obs.inc("dryrun.cells_failed")
            obs.event("dryrun.cell_failed", cell=name,
                      error=type(e).__name__)
            print(f"FAIL {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc(limit=3)
            out_dir.mkdir(parents=True, exist_ok=True)
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            (out_dir / f"{arch}__{shape}__{mesh_name}.json").write_text(
                json.dumps({"arch": arch, "shape": shape, "mesh": mesh_name,
                            "status": "fail", "error": repr(e)}, indent=1))
    print(f"dry-run complete: {len(cells) - failures}/{len(cells)} cells OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
