"""Elastic driver for the shard_map engine: checkpoint/resume + degraded mesh.

``run_elastic_sharded`` is the supervised window loop around the jitted SPMD
runner (the sharded twin of ``HPClust.fit_stream``):

  * every ``ckpt_every`` windows the full ``ShardedState`` (per-group PRNG
    keys, liveness mask, round counter) + round history is host-gathered and
    written through ``ShardedStreamCheckpointer``;
  * a device-loss failure around the runner (``DeviceLostError`` from the
    chaos harness, or a real ``XlaRuntimeError`` matched by message) triggers
    degraded-mesh recovery: the lost devices are excluded, the mesh is
    rebuilt over the survivors (``make_host_mesh(exclude=...)``), the runner
    recompiles, and the state restores from the last checkpoint —
    ``redistribute_state`` keeps the objective-ranked best incumbents when
    the surviving mesh carries fewer worker groups;
  * a crash anywhere else best-effort-saves the last good state before
    re-raising, so a same-mesh resume replays bit-for-bit (the state carries
    the PRNG keys and the global round counter).

Keep-the-best makes all of this safe: a checkpointed incumbent is a complete
restart point and any resumed run can only match-or-improve.
"""
from __future__ import annotations

import functools
from typing import Callable, Iterable, NamedTuple, Optional

import numpy as np

from repro import flags, obs
from repro.core.strategies import HPClustConfig
from repro.data import device_prefetch
from repro.launch.mesh import make_host_mesh
from repro.resilience.sharded_ckpt import (
    ShardedStreamCheckpointer,
    redistribute_state,
)


class DeviceLostError(RuntimeError):
    """A device dropped out mid-collective.

    Raised by the chaos injector ``drop_device_midstream``; real XLA
    failures surface as ``XlaRuntimeError`` and are matched by message in
    ``is_device_loss``. ``lost_devices`` names the dead ``Device.id``s so
    the recovery path can exclude exactly them from the rebuilt mesh.
    """

    def __init__(self, msg: str, lost_devices: Iterable[int] = ()):
        super().__init__(msg)
        self.lost_devices = tuple(lost_devices)


# Substrings (lowercased) that mark an XLA runtime failure as device loss
# rather than a programming error. Deliberately conservative: anything else
# propagates — retrying a genuine bug on a smaller mesh helps nobody.
_LOSS_MARKERS = (
    "device lost",
    "device_lost",
    "data_loss",
    "nccl",
    "socket closed",
    "connection reset",
    "peer down",
    "halted",
)


def is_device_loss(exc: BaseException) -> bool:
    """Does ``exc`` look like a device/interconnect loss (vs a real bug)?"""
    if isinstance(exc, DeviceLostError):
        return True
    if type(exc).__name__ in ("XlaRuntimeError", "JaxRuntimeError"):
        msg = str(exc).lower()
        return any(m in msg for m in _LOSS_MARKERS)
    return False


@functools.lru_cache(maxsize=None)
def _jit_sharded_runner(mesh, cfg, inner_axis="model", pod_axis=None,
                        donate=False):
    """One compiled SPMD runner per (mesh, cfg, donate) — shardings close
    over the mesh, so caching here keeps the compile cache shared across
    windows and across recoveries back onto a previously-seen mesh (JH003).
    ``donate`` is part of the cache key: the donating and non-donating
    programs are distinct executables, so a flag flip can never alias a
    stale entry.

    Returns ``(jitted_runner, reservoir_sharding)``; the sharding is what
    the device-prefetch thread uses to land windows directly in SPMD layout.
    """
    import jax

    from repro.core import sharded

    fn, in_sh, out_sh = sharded.build_sharded_runner(
        mesh, cfg, inner_axis=inner_axis, pod_axis=pod_axis
    )
    jitted = jax.jit(
        fn, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=(0,) if donate else (),
    )
    return jitted, in_sh[1]


class ElasticResult(NamedTuple):
    centroids: np.ndarray        # (k, d) global best over live groups
    objective: float
    state: object                # final host-gathered ShardedState
    history: np.ndarray          # (rounds_total, W_final) f32
    windows_done: int
    workers: int                 # worker groups on the final mesh
    recoveries: int              # degraded-mesh rebuilds performed
    resumed_at: Optional[int]    # window index restored from, or None


def _worker_count(mesh, inner_axis: str) -> int:
    n = 1
    for a in mesh.axis_names:
        if a != inner_axis:
            n *= mesh.shape[a]
    return n


def run_elastic_sharded(
    stream: Iterable[np.ndarray],
    *,
    k: int,
    sample_size: int = 2048,
    rounds_per_window: int = 8,
    strategy: str = "hybrid",
    seed: int = 0,
    checkpoint_dir=None,
    resume: bool = False,
    ckpt_every: int = 1,
    mesh_shape=None,
    inner_axis: str = "model",
    pod_axis: str | None = None,
    max_recoveries: int = 2,
    kmeans_iters: int = 32,
    runner_wrapper: Optional[Callable] = None,
    prefetch: int | bool | None = None,
) -> ElasticResult:
    """Run the sharded engine over ``stream`` windows, elastically.

    ``runner_wrapper`` (chaos hook) wraps the jitted runner — it is
    re-applied after every recompile, so invocation-counted injectors like
    ``drop_device_midstream`` keep their global count across mesh rebuilds.

    ``prefetch`` (default: the ``REPRO_PREFETCH`` depth) double-buffers
    windows onto the mesh: the background thread broadcasts each window to
    the worker groups and ``jax.device_put``s it with the runner's reservoir
    ``NamedSharding`` while the previous window computes. A mesh rebuild
    bumps the placement epoch; windows placed for a dead mesh are re-placed
    from their host copy before the retry.
    """
    import jax

    from repro.core import sharded

    def make_cfg(workers: int) -> HPClustConfig:
        return HPClustConfig(
            k=k, sample_size=sample_size, workers=workers,
            rounds=rounds_per_window, strategy=strategy,
            groups=2 if strategy == "hybrid2" else 1,
            fixed_schedule=True, kmeans_iters=kmeans_iters,
        )

    def wrap(runner):
        return runner_wrapper(runner) if runner_wrapper is not None else runner

    def to_host(state):
        return jax.device_get(state)

    excluded: set[int] = set()
    donate = flags.donate_enabled()
    mesh = make_host_mesh(mesh_shape, exclude=())
    workers = _worker_count(mesh, inner_axis)
    cfg = make_cfg(workers)
    jitted, res_sharding = _jit_sharded_runner(
        mesh, cfg, inner_axis, pod_axis, donate)
    run_fn = wrap(jitted)

    # (epoch, workers, reservoir sharding) — ONE tuple so the prefetch
    # thread reads a consistent placement even while recover() swaps it.
    placement = (0, workers, res_sharding)

    def place(w: np.ndarray):
        e, wk, sh = placement
        return e, jax.device_put(np.broadcast_to(w, (wk,) + w.shape), sh)

    ckpt = (
        ShardedStreamCheckpointer(checkpoint_dir)
        if checkpoint_dir is not None else None
    )

    state = None
    history = np.zeros((0, workers), np.float32)
    windows_done = 0
    resumed_at: Optional[int] = None
    recoveries = 0

    def adopt(snap, *, event: str):
        """Install a checkpoint onto the *current* mesh, re-ranking only on a
        worker-count change (a same-shape resume must replay bit-for-bit)."""
        nonlocal state, history, windows_done, resumed_at
        st, hist = snap.state, snap.history
        if np.asarray(st.best_obj).shape[0] != workers:
            st, hist = redistribute_state(st, hist, workers)
        state = st
        history = np.asarray(hist, np.float32)
        windows_done = snap.windows_done
        resumed_at = snap.windows_done
        obs.event(event, windows_done=snap.windows_done, workers=workers)

    if ckpt is not None and resume:
        snap = ckpt.restore()
        if snap is not None:
            adopt(snap, event="sharded.resumed")

    def recover(exc: BaseException):
        nonlocal mesh, workers, cfg, run_fn, state, history, recoveries
        nonlocal placement
        lost = set(getattr(exc, "lost_devices", ()) or ())
        excluded.update(lost)
        mesh = make_host_mesh(None, exclude=excluded)
        workers_new = _worker_count(mesh, inner_axis)
        obs.event(
            "resilience.mesh_degraded",
            lost_devices=len(lost),
            excluded_total=len(excluded),
            mesh_shape=str(tuple(mesh.devices.shape)),
            workers=workers_new,
        )
        workers = workers_new
        cfg = make_cfg(workers)
        # A degraded mesh is rebuilt 2-axis; if the pod axis did not survive,
        # hybrid2 degrades gracefully to intra-mesh cooperation.
        pa = pod_axis if pod_axis in mesh.axis_names else None
        jitted, res_sh = _jit_sharded_runner(mesh, cfg, inner_axis, pa,
                                             donate)
        run_fn = wrap(jitted)
        # New epoch: windows the prefetch thread placed for the dead mesh
        # are re-placed from their host copy at retry time.
        placement = (placement[0] + 1, workers, res_sh)
        snap = ckpt.restore() if ckpt is not None else None
        if snap is not None:
            adopt(snap, event="sharded.resumed")
        elif state is not None:
            st, hist = redistribute_state(to_host(state), history, workers)
            state, history = st, np.asarray(hist, np.float32)
        recoveries += 1

    # Sanitize stays off (this tier trusts its feed, as before); the thread
    # still overlaps the f32 copy + broadcast + sharded H2D with compute.
    windows_it = device_prefetch.device_stream(
        stream,
        depth=flags.prefetch_depth(prefetch),
        sanitize=False,
        start_at=windows_done,
        place=place,
    )
    try:
        for item in windows_it:
            wi = item.index
            if state is None:
                state = sharded.init_sharded_state(
                    cfg, item.host.shape[1], seed=seed
                )
            while True:
                epoch, reservoir = item.device
                if epoch != placement[0]:
                    # Placed for a mesh that no longer exists: redo the H2D
                    # from the host copy with the surviving mesh's sharding.
                    _, reservoir = place(item.host)
                try:
                    with obs.span("sharded.window", window=wi,
                                  workers=workers):
                        # Donation deletes the input state's buffers even on
                        # a failed step — the host backup keeps the recovery
                        # and crash-save paths readable.
                        backup = to_host(state) if donate else None
                        try:
                            new_state, objs = run_fn(state, reservoir)
                            jax.block_until_ready(new_state)
                        except BaseException:
                            if backup is not None:
                                state = backup
                            raise
                except Exception as e:  # noqa: BLE001 - triaged below
                    if not is_device_loss(e) or recoveries >= max_recoveries:
                        raise
                    recover(e)
                    continue  # retry this window on the degraded mesh
                state = new_state
                history = np.concatenate(
                    [history, np.asarray(objs, np.float32)], axis=0
                )
                windows_done = wi + 1
                obs.inc("sharded.windows")
                if ckpt is not None and windows_done % ckpt_every == 0:
                    ckpt.save(windows_done, to_host(state), history)
                break
    except BaseException:
        # Crash-save the last good state so a resume loses at most the
        # in-flight window (mirrors fit_stream's crash path).
        if ckpt is not None and state is not None and windows_done > 0:
            try:
                ckpt.save(windows_done, to_host(state), history)
            except Exception:  # pragma: no cover - best effort
                pass
        raise
    finally:
        windows_it.close()  # deterministic prefetch-thread shutdown

    if state is None:
        raise ValueError("empty stream: nothing to cluster")

    st_h = to_host(state)
    obj = np.where(
        np.asarray(st_h.alive, bool)
        & np.isfinite(np.asarray(st_h.best_obj, np.float32)),
        np.asarray(st_h.best_obj, np.float32),
        np.inf,
    )
    w = int(np.argmin(obj))
    return ElasticResult(
        centroids=np.asarray(st_h.centroids[w]),
        objective=float(obj[w]),
        state=st_h,
        history=history,
        windows_done=windows_done,
        workers=workers,
        recoveries=recoveries,
        resumed_at=resumed_at,
    )
