"""Elastic driver for the shard_map engine: checkpoint/resume + degraded mesh.

``run_elastic_sharded`` is the supervised window loop around the jitted SPMD
runner (the sharded twin of ``HPClust.fit_stream``):

  * every ``ckpt_every`` windows the full ``ShardedState`` (per-group PRNG
    keys, liveness mask, round counter) + round history is host-gathered and
    written through ``ShardedStreamCheckpointer``;
  * a device-loss failure around the runner (``DeviceLostError`` from the
    chaos harness, or a real ``XlaRuntimeError`` matched by message) triggers
    degraded-mesh recovery: the lost devices are excluded, the mesh is
    rebuilt over the survivors (``make_host_mesh(exclude=...)``), the runner
    recompiles, and the state restores from the last checkpoint —
    ``redistribute_state`` keeps the objective-ranked best incumbents when
    the surviving mesh carries fewer worker groups;
  * a crash anywhere else best-effort-saves the last good state before
    re-raising, so a same-mesh resume replays bit-for-bit (the state carries
    the PRNG keys and the global round counter).

Keep-the-best makes all of this safe: a checkpointed incumbent is a complete
restart point and any resumed run can only match-or-improve.
"""
from __future__ import annotations

import functools
from typing import Callable, Iterable, NamedTuple, Optional

import numpy as np

from repro import obs
from repro.core.strategies import HPClustConfig
from repro.launch.mesh import make_host_mesh
from repro.resilience.sharded_ckpt import (
    ShardedStreamCheckpointer,
    redistribute_state,
)


class DeviceLostError(RuntimeError):
    """A device dropped out mid-collective.

    Raised by the chaos injector ``drop_device_midstream``; real XLA
    failures surface as ``XlaRuntimeError`` and are matched by message in
    ``is_device_loss``. ``lost_devices`` names the dead ``Device.id``s so
    the recovery path can exclude exactly them from the rebuilt mesh.
    """

    def __init__(self, msg: str, lost_devices: Iterable[int] = ()):
        super().__init__(msg)
        self.lost_devices = tuple(lost_devices)


# Substrings (lowercased) that mark an XLA runtime failure as device loss
# rather than a programming error. Deliberately conservative: anything else
# propagates — retrying a genuine bug on a smaller mesh helps nobody.
_LOSS_MARKERS = (
    "device lost",
    "device_lost",
    "data_loss",
    "nccl",
    "socket closed",
    "connection reset",
    "peer down",
    "halted",
)


def is_device_loss(exc: BaseException) -> bool:
    """Does ``exc`` look like a device/interconnect loss (vs a real bug)?"""
    if isinstance(exc, DeviceLostError):
        return True
    if type(exc).__name__ in ("XlaRuntimeError", "JaxRuntimeError"):
        msg = str(exc).lower()
        return any(m in msg for m in _LOSS_MARKERS)
    return False


@functools.lru_cache(maxsize=None)
def _jit_sharded_runner(mesh, cfg, inner_axis="model", pod_axis=None):
    """One compiled SPMD runner per (mesh, cfg) — shardings close over the
    mesh, so caching here keeps the compile cache shared across windows and
    across recoveries back onto a previously-seen mesh (JH003)."""
    import jax

    from repro.core import sharded

    fn, in_sh, out_sh = sharded.build_sharded_runner(
        mesh, cfg, inner_axis=inner_axis, pod_axis=pod_axis
    )
    return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)


class ElasticResult(NamedTuple):
    centroids: np.ndarray        # (k, d) global best over live groups
    objective: float
    state: object                # final host-gathered ShardedState
    history: np.ndarray          # (rounds_total, W_final) f32
    windows_done: int
    workers: int                 # worker groups on the final mesh
    recoveries: int              # degraded-mesh rebuilds performed
    resumed_at: Optional[int]    # window index restored from, or None


def _worker_count(mesh, inner_axis: str) -> int:
    n = 1
    for a in mesh.axis_names:
        if a != inner_axis:
            n *= mesh.shape[a]
    return n


def run_elastic_sharded(
    stream: Iterable[np.ndarray],
    *,
    k: int,
    sample_size: int = 2048,
    rounds_per_window: int = 8,
    strategy: str = "hybrid",
    seed: int = 0,
    checkpoint_dir=None,
    resume: bool = False,
    ckpt_every: int = 1,
    mesh_shape=None,
    inner_axis: str = "model",
    pod_axis: str | None = None,
    max_recoveries: int = 2,
    kmeans_iters: int = 32,
    runner_wrapper: Optional[Callable] = None,
) -> ElasticResult:
    """Run the sharded engine over ``stream`` windows, elastically.

    ``runner_wrapper`` (chaos hook) wraps the jitted runner — it is
    re-applied after every recompile, so invocation-counted injectors like
    ``drop_device_midstream`` keep their global count across mesh rebuilds.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import sharded

    def make_cfg(workers: int) -> HPClustConfig:
        return HPClustConfig(
            k=k, sample_size=sample_size, workers=workers,
            rounds=rounds_per_window, strategy=strategy,
            groups=2 if strategy == "hybrid2" else 1,
            fixed_schedule=True, kmeans_iters=kmeans_iters,
        )

    def wrap(runner):
        return runner_wrapper(runner) if runner_wrapper is not None else runner

    def to_host(state):
        return jax.device_get(state)

    excluded: set[int] = set()
    mesh = make_host_mesh(mesh_shape, exclude=())
    workers = _worker_count(mesh, inner_axis)
    cfg = make_cfg(workers)
    run_fn = wrap(_jit_sharded_runner(mesh, cfg, inner_axis, pod_axis))

    ckpt = (
        ShardedStreamCheckpointer(checkpoint_dir)
        if checkpoint_dir is not None else None
    )

    state = None
    history = np.zeros((0, workers), np.float32)
    windows_done = 0
    resumed_at: Optional[int] = None
    recoveries = 0

    def adopt(snap, *, event: str):
        """Install a checkpoint onto the *current* mesh, re-ranking only on a
        worker-count change (a same-shape resume must replay bit-for-bit)."""
        nonlocal state, history, windows_done, resumed_at
        st, hist = snap.state, snap.history
        if np.asarray(st.best_obj).shape[0] != workers:
            st, hist = redistribute_state(st, hist, workers)
        state = st
        history = np.asarray(hist, np.float32)
        windows_done = snap.windows_done
        resumed_at = snap.windows_done
        obs.event(event, windows_done=snap.windows_done, workers=workers)

    if ckpt is not None and resume:
        snap = ckpt.restore()
        if snap is not None:
            adopt(snap, event="sharded.resumed")

    def recover(exc: BaseException):
        nonlocal mesh, workers, cfg, run_fn, state, history, recoveries
        lost = set(getattr(exc, "lost_devices", ()) or ())
        excluded.update(lost)
        mesh = make_host_mesh(None, exclude=excluded)
        workers_new = _worker_count(mesh, inner_axis)
        obs.event(
            "resilience.mesh_degraded",
            lost_devices=len(lost),
            excluded_total=len(excluded),
            mesh_shape=str(tuple(mesh.devices.shape)),
            workers=workers_new,
        )
        workers = workers_new
        cfg = make_cfg(workers)
        # A degraded mesh is rebuilt 2-axis; if the pod axis did not survive,
        # hybrid2 degrades gracefully to intra-mesh cooperation.
        pa = pod_axis if pod_axis in mesh.axis_names else None
        run_fn = wrap(_jit_sharded_runner(mesh, cfg, inner_axis, pa))
        snap = ckpt.restore() if ckpt is not None else None
        if snap is not None:
            adopt(snap, event="sharded.resumed")
        elif state is not None:
            st, hist = redistribute_state(to_host(state), history, workers)
            state, history = st, np.asarray(hist, np.float32)
        recoveries += 1

    try:
        for wi, window in enumerate(stream):
            if wi < windows_done:
                continue  # consumed before the resume point
            window = np.asarray(window, np.float32)
            if state is None:
                state = sharded.init_sharded_state(
                    cfg, window.shape[1], seed=seed
                )
            while True:
                reservoir = np.broadcast_to(
                    window, (workers,) + window.shape
                )
                try:
                    with obs.span("sharded.window", window=wi,
                                  workers=workers):
                        new_state, objs = run_fn(
                            state, jnp.asarray(reservoir)
                        )
                        jax.block_until_ready(new_state)
                except Exception as e:  # noqa: BLE001 - triaged below
                    if not is_device_loss(e) or recoveries >= max_recoveries:
                        raise
                    recover(e)
                    continue  # retry this window on the degraded mesh
                state = new_state
                history = np.concatenate(
                    [history, np.asarray(objs, np.float32)], axis=0
                )
                windows_done = wi + 1
                obs.inc("sharded.windows")
                if ckpt is not None and windows_done % ckpt_every == 0:
                    ckpt.save(windows_done, to_host(state), history)
                break
    except BaseException:
        # Crash-save the last good state so a resume loses at most the
        # in-flight window (mirrors fit_stream's crash path).
        if ckpt is not None and state is not None and windows_done > 0:
            try:
                ckpt.save(windows_done, to_host(state), history)
            except Exception:  # pragma: no cover - best effort
                pass
        raise

    if state is None:
        raise ValueError("empty stream: nothing to cluster")

    st_h = to_host(state)
    obj = np.where(
        np.asarray(st_h.alive, bool)
        & np.isfinite(np.asarray(st_h.best_obj, np.float32)),
        np.asarray(st_h.best_obj, np.float32),
        np.inf,
    )
    w = int(np.argmin(obj))
    return ElasticResult(
        centroids=np.asarray(st_h.centroids[w]),
        objective=float(obj[w]),
        state=st_h,
        history=history,
        windows_done=windows_done,
        workers=workers,
        recoveries=recoveries,
        resumed_at=resumed_at,
    )
