"""End-to-end training driver.

CPU-runnable at smoke scale; the same code path the dry-run lowers for the
production mesh (steps.make_train_step + sharding rules + Trainer fault
tolerance).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import functools
import json

import jax
import numpy as np

from repro import flags, obs
from repro.configs import get_config
from repro.data import token_batches
from repro.launch import steps as S
from repro.models import model as M
from repro.runtime import Trainer, TrainerConfig


@functools.lru_cache(maxsize=None)
def _jit_train_step(cfg, lr_steps: int, donate: bool = False):
    """One compiled train step per (cfg, schedule, donate) — cached so
    repeated main() invocations in one process (tests) share the compile
    cache (JH003). ``donate`` reuses the params/opt_state buffers for the
    step outputs (REPRO_DONATE); it keys the cache so the donating and
    copying programs never alias. Checkpointing stays safe because
    ``CheckpointManager.save`` host-gathers synchronously BEFORE the next
    step can donate the saved buffers (see runtime/trainer.py)."""
    step = S.make_train_step(cfg, lr_steps=lr_steps, grad_accum=1)
    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def batches_for(cfg, batch, seq, seed=0):
    if cfg.family == "encdec":
        rng = np.random.default_rng(seed)
        while True:
            yield {
                "frames": rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32),
                "tokens": rng.integers(
                    0, cfg.vocab_size, (batch, max(4, seq // cfg.dec_ratio))
                ).astype(np.int32),
            }
    elif cfg.family == "vlm":
        rng = np.random.default_rng(seed)
        base = token_batches(cfg.vocab_size, batch, seq - cfg.img_tokens, seed=seed)
        for b in base:
            yield {
                "img_embeds": rng.normal(
                    size=(batch, cfg.img_tokens, cfg.d_model)).astype(np.float32),
                "tokens": b["tokens"],
            }
    else:
        yield from token_batches(cfg.vocab_size, batch, seq, seed=seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL trace to PATH (read with "
                         "`python -m repro.obs summarize PATH`)")
    args = ap.parse_args(argv)

    if args.trace:
        obs.configure(jsonl=args.trace)
    try:
        cfg = get_config(args.arch, smoke=args.smoke)
        step_fn = _jit_train_step(cfg, args.steps, flags.donate_enabled())
        opt = step_fn.__wrapped__.optimizer

        def init_state():
            params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
            return params, opt.init(params)

        trainer = Trainer(
            TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir),
            step_fn, init_state,
            batches_for(cfg, args.batch, args.seq, args.seed),
        )
        result = trainer.run()
        losses = [m["loss"] for m in trainer.metrics_log if "loss" in m]
        result["loss_first"] = losses[0] if losses else None
        result["loss_last"] = losses[-1] if losses else None
        result["loss_min"] = min(losses) if losses else None
        print(json.dumps(result, indent=1))
        return 0
    finally:
        obs.shutdown()


if __name__ == "__main__":
    raise SystemExit(main())
