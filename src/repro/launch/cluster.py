"""The paper's end-to-end driver: HPClust over an infinite synthetic stream.

  PYTHONPATH=src python -m repro.launch.cluster --strategy hybrid \
      --k 10 --sample 2048 --workers 4 --rounds 24 --windows 4
"""
from __future__ import annotations

import argparse
import json
import time

from repro import obs
from repro.core import HPClust, HPClustConfig
from repro.core.hpclust import stream_from_generator
from repro.data import blob_stream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="hybrid",
                    choices=("inner", "competitive", "cooperative", "hybrid",
                             "hybrid2"))
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--sample", type=int, default=2048)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=8, help="rounds per window")
    ap.add_argument("--windows", type=int, default=4)
    ap.add_argument("--window-size", type=int, default=65536)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint worker state every window (resumable)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint every N windows (with --ckpt-dir)")
    ap.add_argument("--sharded", action="store_true",
                    help="run the shard_map SPMD engine over the local "
                         "devices (the production code path at host scale)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL trace to PATH (read with "
                         "`python -m repro.obs summarize PATH`)")
    args = ap.parse_args(argv)

    if args.trace:
        obs.configure(jsonl=args.trace)
    try:
        if args.sharded:
            return _main_sharded(args)
        return _main_stream(args)
    finally:
        obs.shutdown()


def _main_stream(args):
    cfg = HPClustConfig(
        k=args.k, sample_size=args.sample, workers=args.workers,
        rounds=args.rounds, strategy=args.strategy,
        groups=2 if args.strategy == "hybrid2" else 1,
    )
    hp = HPClust(cfg, seed=args.seed)
    stream = stream_from_generator(
        blob_stream(args.window_size, n=args.dim, k=args.k, seed=args.seed),
        args.windows,
    )
    t0 = time.time()
    res = hp.fit_stream(
        stream, checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every, resume=args.resume,
    )
    dt = time.time() - t0
    # evaluate on a fresh holdout window from the SAME stream distribution
    holdout = next(iter(
        blob_stream(200000, n=args.dim, k=args.k, seed=args.seed)
    ))
    full_obj = hp.objective(holdout, res.centroids)
    print(json.dumps({
        "strategy": args.strategy,
        "sample_objective": res.objective,
        "holdout_objective": full_obj,
        "rounds_total": int(res.history.shape[0]),
        "windows": res.stats.windows if res.stats else None,
        "sanitized_rows": res.stats.sanitized_rows if res.stats else None,
        "resumed_at": res.stats.resumed_at if res.stats else None,
        "wall_s": round(dt, 2),
    }, indent=1))
    return 0




def _main_sharded(args):
    """The production (shard_map) engine over whatever devices exist.

    Workers over the `data` axis, inner (distance) parallelism over `model`.
    With one CPU device this degrades to a 1x1 mesh — same program the
    512-chip dry-run lowers. Runs through the elastic driver, so
    --ckpt-dir/--resume/--ckpt-every behave exactly like the single-host
    path and a device loss mid-stream degrades the mesh instead of killing
    the run (see repro.launch.elastic).
    """
    import numpy as np

    from repro.launch.elastic import run_elastic_sharded

    stream = stream_from_generator(
        blob_stream(args.window_size, n=args.dim, k=args.k, seed=args.seed),
        args.windows,
    )
    t0 = time.time()
    res = run_elastic_sharded(
        stream,
        k=args.k, sample_size=args.sample,
        rounds_per_window=args.rounds, strategy=args.strategy,
        seed=args.seed,
        checkpoint_dir=args.ckpt_dir, resume=args.resume,
        ckpt_every=args.ckpt_every,
    )
    print(json.dumps({
        "strategy": args.strategy, "engine": "shard_map",
        "workers": res.workers,
        "best_sample_objective": res.objective,
        "monotone": bool(
            (np.diff(res.history, axis=0) <= 1e-3).all()
        ) if res.history.size else True,
        "rounds_total": int(res.history.shape[0]),
        "windows": res.windows_done,
        "recoveries": res.recoveries,
        "resumed_at": res.resumed_at,
        "wall_s": round(time.time() - t0, 2),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
