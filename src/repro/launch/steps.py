"""Step functions (train / prefill / decode) + abstract input specs per cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step the shape exercises — weak-type-correct, shardable, no
device allocation — the dry-run lowers against these.

Shapes (assignment): train_4k (train_step), prefill_32k (serve_prefill),
decode_32k / long_500k (serve_step: 1 new token against a seq_len cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.common import ModelConfig
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer

SHAPES: dict[str, dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def cell_is_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.supports_long_context
    return True


def batch_structs(cfg: ModelConfig, seq_len: int, batch: int) -> dict[str, Any]:
    tok = jnp.int32
    if cfg.family == "encdec":
        return {
            "frames": jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                           jnp.dtype(cfg.dtype)),
            "tokens": jax.ShapeDtypeStruct((batch, seq_len // cfg.dec_ratio), tok),
        }
    if cfg.family == "vlm":
        return {
            "img_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.img_tokens, cfg.d_model), jnp.dtype(cfg.dtype)),
            "tokens": jax.ShapeDtypeStruct((batch, seq_len - cfg.img_tokens), tok),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq_len), tok)}


def make_train_step(cfg: ModelConfig, *, lr_steps: int = 10000,
                    grad_accum: int | None = None) -> Callable:
    opt = make_optimizer(cfg.optimizer, cosine_schedule(3e-4, lr_steps))
    accum = grad_accum if grad_accum is not None else cfg.grad_accum
    gdt = jnp.dtype(cfg.grad_dtype)

    def train_step(params, opt_state, batch):
        if accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )

            def micro_step(acc, mb):
                gsum, lsum = acc
                loss, grads = jax.value_and_grad(
                    lambda p: M.loss_fn(cfg, p, mb)
                )(params)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(gdt), gsum, grads
                )
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            init = (g0, jnp.zeros((), jnp.float32))
            if cfg.unroll:  # flat HLO for roofline calibration
                carry = init
                for i in range(accum):
                    carry, _ = micro_step(
                        carry, jax.tree.map(lambda x: x[i], micro)
                    )
                gsum, lsum = carry
            else:
                (gsum, lsum), _ = jax.lax.scan(micro_step, init, micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            loss, grads = jax.value_and_grad(
                lambda p: M.loss_fn(cfg, p, batch)
            )(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    train_step.optimizer = opt  # used by the dry-run for state specs/structs
    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def serve_prefill(params, batch):
        return M.prefill(cfg, params, batch)
    return serve_prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, tokens, pos, caches):
        return M.decode_step(cfg, params, tokens, pos, caches)
    return serve_step


def opt_state_structs(cfg: ModelConfig, opt) -> Any:
    shapes = M.param_shapes(cfg)
    return jax.eval_shape(opt.init, shapes)


def input_specs(cfg: ModelConfig, shape: str) -> dict[str, Any]:
    """Abstract inputs for the cell's step function."""
    meta = SHAPES[shape]
    s, b = meta["seq_len"], meta["global_batch"]
    if meta["kind"] == "train":
        return {"batch": batch_structs(cfg, s, b)}
    if meta["kind"] == "prefill":
        return {"batch": batch_structs(cfg, s, b)}
    # decode: 1 new token against a cache of length seq_len
    enc_len = s if cfg.family == "encdec" else 0
    smax = s // cfg.dec_ratio if cfg.family == "encdec" else s
    caches = M.init_cache(cfg, b, smax, enc_len=enc_len, abstract=True)
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }
