"""Mixture-of-Experts with sort-based capacity dispatch (EP over `model`).

TPUs have no fast scatter, and the naive one-hot dispatch einsum costs
O(T * E * C * d) — dead FLOPs that would swamp the roofline for 256-expert
models. Instead we sort token-slots by expert id, place them into an
(E, capacity, d) buffer with position-in-expert indices derived from a
cumulative histogram (drop-on-overflow, like GShard/Switch with
capacity_factor), run the expert FFNs as one batched einsum over the E axis
(sharded over `model` = expert parallelism), and combine back with the
routing weights. All data movement is gather/scatter (O(T*k*d) bytes), all
FLOPs are the honest active-expert compute: E*C ≈ T*top_k*capacity_factor.

Routers:
  softmax  — softmax probs -> top-k -> renormalized weights (Qwen3-MoE).
  sigmoid  — per-expert sigmoid scores; top-k chosen on score + a learned
             balancing bias (aux-loss-free, DeepSeek-V3); weights are the
             unbiased scores renormalized over the chosen experts.

A switch-style load-balance loss is returned for the softmax router
(coefficient applied by the caller); the sigmoid router returns the mean
violation statistic used to adapt the bias (reported, not back-propagated).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Table

Array = jax.Array


def moe_table(cfg: ModelConfig) -> Table:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_moe
    t: Table = {
        "router": ((d, e), ("embed", None), "normal"),
        "wg": ((e, d, ff), ("experts", "embed", "mlp"), "normal"),
        "wu": ((e, d, ff), ("experts", "embed", "mlp"), "normal"),
        "wd": ((e, ff, d), ("experts", "mlp", "embed"), "normal"),
    }
    if cfg.router_type == "sigmoid":
        t["router_bias"] = ((e,), (None,), "zeros")
    if cfg.n_shared_experts:
        sf = cfg.d_ff_moe * cfg.n_shared_experts
        t["shared/wg"] = ((d, sf), ("embed", "mlp"), "normal")
        t["shared/wu"] = ((d, sf), ("embed", "mlp"), "normal")
        t["shared/wd"] = ((sf, d), ("mlp", "embed"), "normal")
    return t


def _route(p: Mapping[str, Array], pre: str, x: Array, cfg: ModelConfig):
    """x (T, d) -> (weights (T, k), expert_ids (T, k), aux_loss ())."""
    logits = (x.astype(jnp.float32)) @ p[f"{pre}router"].astype(jnp.float32)
    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        biased = scores + p[f"{pre}router_bias"].astype(jnp.float32)[None, :]
        _, ids = jax.lax.top_k(biased, cfg.top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        # Report load imbalance (drives the bias update on the host side).
        load = jnp.mean(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
        aux = jnp.sum((load - 1.0 / cfg.n_experts) ** 2)
        return w, ids, aux
    probs = jax.nn.softmax(logits, axis=-1)
    topw, ids = jax.lax.top_k(probs, cfg.top_k)
    w = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    # Switch-style balance loss: E * <f_e * P_e>.
    f = jnp.mean(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    pmean = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f * pmean)
    return w, ids, aux


def _dispatch_group(xt, w, ids, wg, wu, wd, cap: int, e: int, k: int):
    """Sort-dispatch one token group. xt (Tg, d); w/ids (Tg, k)."""
    t = xt.shape[0]
    flat_e = ids.reshape(-1)                        # (Tg*k,)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_w = w.reshape(-1)

    order = jnp.argsort(flat_e)                     # group-LOCAL sort
    se = flat_e[order]
    stok = flat_tok[order]
    sw = flat_w[order]

    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k) - starts[se]
    keep = pos_in_e < cap
    dest = se * cap + jnp.where(keep, pos_in_e, 0)

    xs = xt[stok]
    buf = jnp.zeros((e * cap, xt.shape[1]), xt.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], xs, 0.0))
    buf = buf.reshape(e, cap, xt.shape[1])

    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g) * u
    yb = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e * cap, -1)

    ys = yb[dest] * (keep[:, None] * sw[:, None]).astype(yb.dtype)
    return jnp.zeros_like(xt).at[stok].add(ys)


def moe_forward(
    p: Mapping[str, Array],
    x: Array,
    cfg: ModelConfig,
    *,
    prefix: str = "",
    capacity_factor: float | None = None,
):
    """x (B, S, d) -> (y (B, S, d), aux_loss ()).

    Tokens are split into ``G`` groups and sort-dispatched *group-locally*
    (vmapped): a single global argsort over 1M token-slots cannot be
    partitioned by GSPMD and forces full replication of the dispatch
    tensors (observed: +90 GB/device on deepseek train). With groups
    sharded over the DP axes and experts over `model`, every dispatch
    tensor stays distributed. Capacity is per group (more drops under
    skew — the standard GShard/MaxText trade; the balance losses keep
    skew small).
    """
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    cf = capacity_factor or cfg.capacity_factor

    # group count: honor cfg.moe_groups but keep >= ~2k tokens per group
    # and divide T evenly.
    groups = min(cfg.moe_groups, max(1, t // 2048))
    while t % groups:
        groups -= 1
    tg = t // groups
    cap = min(max(1, int(-(-tg * k * cf // e))), tg)

    xt = x.reshape(t, d)
    w, ids, aux = _route(p, pre, xt, cfg)  # (T,k), (T,k)

    # The (G, Tg, d) regrouping is 3D again: re-pin it to the activation
    # sharding (groups over DP). The (T, d) flatten escapes the block-level
    # constraint and GSPMD otherwise replicates the dispatch stream
    # (+~180 GB/device on deepseek prefill; EXPERIMENTS.md It.2c).
    from repro.models import model as _model
    xg = _model._constrain(xt.reshape(groups, tg, d))
    wg_ = w.reshape(groups, tg, k)
    ig = ids.reshape(groups, tg, k)
    y = jax.vmap(
        lambda xx, ww, ii: _dispatch_group(
            xx, ww, ii, p[f"{pre}wg"], p[f"{pre}wu"], p[f"{pre}wd"],
            cap, e, k)
    )(xg, wg_, ig)
    y = _model._constrain(y)
    y = y.reshape(b, s, d)

    if cfg.n_shared_experts:
        gs = xt @ p[f"{pre}shared/wg"]
        us = xt @ p[f"{pre}shared/wu"]
        y = y + ((jax.nn.silu(gs) * us) @ p[f"{pre}shared/wd"]).reshape(b, s, d)
    return y, aux
