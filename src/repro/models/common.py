"""Model substrate: config schema, parameter tables, norms, RoPE, embeddings.

Parameters are declared once per architecture as a *table*:
``name -> (shape, logical_axes, init_kind)``. From one table we derive
  * initialized parameter pytrees (train),
  * ShapeDtypeStruct pytrees (dry-run lowering, no allocation),
  * PartitionSpec pytrees via the deployment's logical-axis rules
    (``repro.distributed.sharding``).

Stacked (scanned) layers simply prepend a "layers" axis to every entry of the
block table — a single source of truth for shapes, sharding and init.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0      # 0 -> d_model // n_heads
    # attention
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int = 0            # sliding window for local layers (gemma3: 1024)
    local_ratio: int = 0       # N local layers per 1 global (gemma3: 5)
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_moe: int = 0
    n_dense_layers: int = 0    # leading dense layers (deepseek: 3)
    router_type: str = "softmax"   # softmax | sigmoid (deepseek aux-free)
    capacity_factor: float = 1.25
    moe_groups: int = 128      # dispatch groups (group-local sorts; see moe.py)
    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0        # zamba2: shared attention block period
    # xlstm
    slstm_every: int = 0       # one sLSTM per N blocks (8 -> "7:1")
    # enc-dec (whisper)
    enc_layers: int = 0
    dec_ratio: int = 8         # decoder len = encoder len // dec_ratio
    # vlm (llava)
    img_tokens: int = 0
    # training / runtime policy
    tie_embeddings: bool = True
    mtp_depth: int = 0
    optimizer: str = "adamw"   # adamw | adafactor (671B-class)
    grad_accum: int = 1        # microbatches per step (activation memory)
    grad_dtype: str = "float32"  # accumulation buffer dtype (bf16 for 671B)
    q_chunk: int = 1024        # attention q-chunk for the triangular schedule
    dtype: str = "bfloat16"
    # Roofline calibration hooks: override segment group counts, e.g.
    # (("moe", 2),), and/or unroll the segment loops into flat HLO. XLA cost
    # analysis counts while bodies ONCE regardless of trip count, so the
    # dry-run compiles small *unrolled* variants (n=1 vs n=2) and
    # extrapolates affinely (see launch/dryrun.py).
    plan_override: tuple[tuple[str, int], ...] = ()
    unroll: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def activ_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_causal_lm(self) -> bool:
        return self.family in ("dense", "moe", "hybrid", "ssm", "vlm")

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode memory growth: SSM/hybrid state or sliding
        window on most layers (DESIGN.md SS5)."""
        return self.family in ("hybrid", "ssm") or self.local_ratio > 0


# ---------------------------------------------------------------------------
# Parameter tables
# ---------------------------------------------------------------------------

# entry: (shape, logical_axes, init_kind). init kinds:
#   "normal"    fan-in scaled normal (1/sqrt(fan_in))
#   "embed"     N(0, 1) * d^-0.5-free (standard embedding init)
#   "zeros", "ones"
#   "ssm_a"     mamba A_log init, "ssm_dt" dt bias init
Entry = tuple[tuple[int, ...], tuple[str | None, ...], str]
Table = dict[str, Entry]


def stack_table(table: Table, n: int, axis_name: str = "layers") -> Table:
    return {
        k: ((n,) + shape, (axis_name,) + logical, kind)
        for k, (shape, logical, kind) in table.items()
    }


def prefix_table(table: Table, prefix: str) -> Table:
    return {f"{prefix}/{k}": v for k, v in table.items()}


def _init_leaf(key: Array, shape, kind: str, dtype) -> Array:
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "embed":
        return (jax.random.normal(key, shape) * 0.02).astype(dtype)
    if kind == "ssm_a":
        # A_log ~ log(uniform[1,16]) (mamba2 init); stored positive.
        u = jax.random.uniform(key, shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(dtype)
    if kind == "ssm_dt":
        # dt bias: softplus^-1 of dt ~ loguniform[1e-3, 1e-1]
        dt = jnp.exp(
            jax.random.uniform(key, shape)
            * (math.log(1e-1) - math.log(1e-3))
            + math.log(1e-3)
        )
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    if kind == "normal":
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(key, shape) / math.sqrt(max(fan_in, 1))).astype(dtype)
    raise ValueError(f"unknown init kind {kind!r}")


def init_from_table(key: Array, table: Table, dtype=jnp.float32) -> dict[str, Array]:
    """Deterministic per-name keys: robust to table ordering changes."""
    out = {}
    for name, (shape, _, kind) in sorted(table.items()):
        sub = jax.random.fold_in(key, hash(name) % (1 << 31))
        out[name] = _init_leaf(sub, shape, kind, dtype)
    return out


def shapes_from_table(table: Table, dtype=jnp.float32) -> dict[str, jax.ShapeDtypeStruct]:
    return {
        name: jax.ShapeDtypeStruct(shape, dtype)
        for name, (shape, _, _) in table.items()
    }


def specs_from_table(
    table: Table, rules: Mapping[str, str | tuple[str, ...] | None]
) -> dict[str, jax.sharding.PartitionSpec]:
    from jax.sharding import PartitionSpec as P

    out = {}
    for name, (shape, logical, _) in table.items():
        axes = tuple(rules.get(ax) if ax is not None else None for ax in logical)
        out[name] = P(*axes)
    return out


# ---------------------------------------------------------------------------
# Primitive layers (functional; params indexed by name)
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_freqs(positions: Array, dim: int, theta: float) -> tuple[Array, Array]:
    """positions (...,) -> cos/sin (..., dim//2)."""
    half = dim // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x (..., S, H, hd); cos/sin (..., S, hd//2) — rotate-half convention."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


def gated_mlp(params: Mapping[str, Array], prefix: str, x: Array) -> Array:
    """SwiGLU MLP: silu(x W_gate) * (x W_up) W_down."""
    g = x @ params[f"{prefix}/wg"]
    u = x @ params[f"{prefix}/wu"]
    return (jax.nn.silu(g) * u) @ params[f"{prefix}/wd"]


def mlp_table(cfg: ModelConfig, d_ff: int | None = None) -> Table:
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    return {
        "wg": ((d, ff), ("embed", "mlp"), "normal"),
        "wu": ((d, ff), ("embed", "mlp"), "normal"),
        "wd": ((ff, d), ("mlp", "embed"), "normal"),
    }


def sinusoidal_positions(s: int, d: int) -> Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy_loss(logits: Array, labels: Array, *, z_loss: float = 1e-4) -> Array:
    """Mean NLL with a small z-loss (logit-norm regularizer; stabilizes bf16).

    The label pick is a one-hot *contraction*, not take_along_axis: a gather
    along a vocab-sharded axis makes GSPMD all-gather the full (B,S,V) f32
    logits per shard (~5 GB/microbatch at V=152k — measured +20 GB/device on
    qwen1.5 train, EXPERIMENTS.md It.2a). The contraction reduces over the
    sharded axis with a per-shard partial + psum instead.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.sum(logits * onehot, axis=-1)
    nll = lse - ll
    return jnp.mean(nll) + z_loss * jnp.mean(lse * lse)
