"""Mamba2 (SSD) blocks — chunked-parallel training, O(1)-state decode.

Training/prefill runs the SSD chunkwise algorithm: the sequence is split
into chunks of ``ssm_chunk``; intra-chunk interactions are dense
attention-like matmuls (MXU-friendly), inter-chunk interactions flow through
the (H, N, P) state carried by a short ``lax.scan`` over chunks. Decode is
the pure recurrence: state' = exp(dt*A) state + dt * B ⊗ x.

This is the TPU-native adaptation of the CUDA SSD kernel: the chunk
decomposition is the same, but instead of a fused kernel we emit batched
einsums XLA maps onto the MXU, and the scan carries only the O(B*H*N*P)
state (DESIGN.md SS5).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Table, rms_norm

Array = jax.Array


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    hd = 64
    heads = d_in // hd
    return d_in, heads, hd, cfg.ssm_state


def mamba_table(cfg: ModelConfig) -> Table:
    d = cfg.d_model
    d_in, heads, _, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        # in_proj -> [z (d_in), xBC (d_in + 2N), dt (H)]
        "in_proj": ((d, 2 * d_in + 2 * n + heads), ("embed", "mlp"), "normal"),
        "conv_w": ((cfg.ssm_conv, conv_ch), (None, "mlp"), "normal"),
        "conv_b": ((conv_ch,), ("mlp",), "zeros"),
        "a_log": ((heads,), (None,), "ssm_a"),
        "d_skip": ((heads,), (None,), "ones"),
        "dt_bias": ((heads,), (None,), "ssm_dt"),
        "norm": ((d_in,), ("mlp",), "ones"),
        "out_proj": ((d_in, d), ("mlp", "embed"), "normal"),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. x (B, S, C), w (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],  # (W, 1, C) — depthwise via feature_group_count
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def _ssd_chunked(x, dt, a, b_in, c_in, chunk: int, state0=None):
    """SSD scan.

    x (B,S,H,P), dt (B,S,H) [post-softplus], a (H,) [negative],
    b_in/c_in (B,S,N). Returns y (B,S,H,P), final state (B,H,N,P).
    """
    bsz, s_orig, h, p_dim = x.shape
    n = b_in.shape[-1]
    q = min(chunk, s_orig)
    pad = (-s_orig) % q
    if pad:
        # Padding steps carry dt=0: decay exp(0)=1 and zero contribution, so
        # the final state is exact; padded y rows are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // q
    f32 = jnp.float32

    xc = x.reshape(bsz, nc, q, h, p_dim).astype(f32)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    bc = b_in.reshape(bsz, nc, q, n).astype(f32)
    cc = c_in.reshape(bsz, nc, q, n).astype(f32)

    da = dtc * a[None, None, None, :]           # (B,nc,Q,H) negative increments
    cs = jnp.cumsum(da, axis=2)                  # inclusive cumsum within chunk
    total = cs[:, :, -1, :]                      # (B,nc,H)

    xdt = xc * dtc[..., None]                    # (B,nc,Q,H,P)

    # Intra-chunk (block-diagonal) term.
    gmat = jnp.einsum("bcqn,bckn->bcqk", cc, bc)            # (B,nc,Q,Q)
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    m = jnp.where(tri, gmat[..., None] * decay, 0.0)        # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xdt)

    # Per-chunk state contribution: sum_j exp(total - cs_j) dt_j B_j x_j^T.
    w_state = jnp.exp(total[:, :, None, :] - cs)            # (B,nc,Q,H)
    s_chunk = jnp.einsum("bckn,bckh,bckhp->bchnp", bc, w_state * dtc, xc)

    # Inter-chunk recurrence over nc.
    if state0 is None:
        state0 = jnp.zeros((bsz, h, n, p_dim), f32)

    def scan_fn(carry, inp):
        s_prev = carry
        s_c, tot = inp  # (B,H,N,P), (B,H)
        s_new = s_prev * jnp.exp(tot)[:, :, None, None] + s_c
        return s_new, s_prev

    s_chunk_t = jnp.moveaxis(s_chunk, 1, 0)   # (nc,B,H,N,P)
    total_t = jnp.moveaxis(total, 1, 0)       # (nc,B,H)
    final_state, s_prevs = jax.lax.scan(scan_fn, state0, (s_chunk_t, total_t))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)     # (B,nc,H,N,P) state at chunk start

    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", cc, s_prevs, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(bsz, s, h, p_dim)[:, :s_orig]
    return y, final_state


def mamba_forward(
    p: Mapping[str, Array],
    x: Array,
    cfg: ModelConfig,
    *,
    prefix: str = "",
    return_cache: bool = False,
):
    """Train/prefill. x (B,S,d). Cache = (ssm_state (B,H,N,P), conv_tail)."""
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    bsz, s, _ = x.shape
    d_in, heads, hd, n = _dims(cfg)

    zxbcdt = x @ p[f"{pre}in_proj"]
    z = zxbcdt[..., :d_in]
    xbc_raw = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt_pre = zxbcdt[..., 2 * d_in + 2 * n :]

    xbc = jax.nn.silu(_causal_conv(xbc_raw, p[f"{pre}conv_w"], p[f"{pre}conv_b"]))
    xs = xbc[..., :d_in].reshape(bsz, s, heads, hd)
    b_in = xbc[..., d_in : d_in + n]
    c_in = xbc[..., d_in + n :]

    dt = jax.nn.softplus(
        dt_pre.astype(jnp.float32) + p[f"{pre}dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p[f"{pre}a_log"].astype(jnp.float32))

    y, state = _ssd_chunked(xs, dt, a, b_in, c_in, cfg.ssm_chunk)
    y = y + p[f"{pre}d_skip"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p[f"{pre}norm"])
    out = y @ p[f"{pre}out_proj"]
    if return_cache:
        # conv ring: last (width-1) *pre-conv* channel rows.
        width = cfg.ssm_conv
        tail = xbc_raw[:, -(width - 1) :, :]
        pad = (width - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, (state, tail)
    return out


def mamba_decode(
    p: Mapping[str, Array],
    x: Array,
    cache: tuple[Array, Array],
    cfg: ModelConfig,
    *,
    prefix: str = "",
):
    """One-token recurrence. x (B,1,d); cache (state (B,H,N,P), conv_tail)."""
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    bsz = x.shape[0]
    d_in, heads, hd, n = _dims(cfg)
    state, conv_tail = cache  # conv_tail (B, width-1, C)

    zxbcdt = x[:, 0, :] @ p[f"{pre}in_proj"]  # (B, *)
    z = zxbcdt[..., :d_in]
    xbc_new = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt_pre = zxbcdt[..., 2 * d_in + 2 * n :]

    # causal depthwise conv over [tail, new]
    w = p[f"{pre}conv_w"]  # (W, C)
    hist = jnp.concatenate([conv_tail, xbc_new[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p[f"{pre}conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_tail = hist[:, 1:, :]

    xs = xbc[..., :d_in].reshape(bsz, heads, hd)
    b_in = xbc[..., d_in : d_in + n]
    c_in = xbc[..., d_in + n :]
    dt = jax.nn.softplus(
        dt_pre.astype(jnp.float32) + p[f"{pre}dt_bias"].astype(jnp.float32)
    )  # (B,H)
    a = -jnp.exp(p[f"{pre}a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])  # (B,H)

    upd = jnp.einsum("bn,bh,bhp->bhnp", b_in.astype(jnp.float32), dt, xs.astype(jnp.float32))
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c_in.astype(jnp.float32), state)
    y = y + p[f"{pre}d_skip"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p[f"{pre}norm"])
    out = (y @ p[f"{pre}out_proj"])[:, None, :]
    return out, (state, new_tail)
