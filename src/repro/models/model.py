"""Architecture assembly: segments of scanned blocks + train/prefill/decode.

Every assigned architecture is described by a *plan*: an ordered list of
``Segment(name, n, kinds)``. A segment scans ``n`` groups; within a group the
``kinds`` list is unrolled in python (e.g. gemma3's ``5x local + 1 global``,
zamba2's ``6x mamba + shared-attn``, xlstm's ``7x mlstm + slstm``). Parameters
of block j in a segment are stacked over the n groups, so HLO stays small
(one while loop per segment) and remat applies per group.

Shared (weight-tied) blocks — zamba2's attention — live outside the segment
stacks and are closed over by every group (exact Zamba2 sharing scheme).

Caches for decode mirror the parameter layout: cache[segment][j] is a pytree
stacked over n, consumed/produced as scan xs/ys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (
    ModelConfig,
    Table,
    cross_entropy_loss,
    gated_mlp,
    init_from_table,
    layer_norm,
    mlp_table,
    prefix_table,
    rms_norm,
    shapes_from_table,
    sinusoidal_positions,
    specs_from_table,
    stack_table,
)

Array = jax.Array

# Activation sharding constraint, set by the launcher (dry-run / trainer)
# before tracing: a PartitionSpec applied to the (B, S, d) residual stream
# at every block boundary. Without it GSPMD tends to leave the scan residual
# stack replicated, which blows per-device temp memory (see DESIGN.md SS4).
_ACTIVATION_SPEC: list = [None]


def set_activation_spec(spec) -> None:
    _ACTIVATION_SPEC[0] = spec


def _constrain(x: Array) -> Array:
    spec = _ACTIVATION_SPEC[0]
    if spec is None or x.ndim != 3:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # no mesh in scope (e.g. plain-jit CPU tests) — a
        return x       # sharding hint is best-effort by design


# Cache sharding policy (shape -> PartitionSpec | None), set by the launcher.
# Without it the scan-stacked cache ys of prefill default to REPLICATED
# (measured: +180 GB/device on deepseek prefill_32k; EXPERIMENTS.md It.2b).
_CACHE_SPEC_FN: list = [None]


def set_cache_spec_fn(fn) -> None:
    _CACHE_SPEC_FN[0] = fn


def _constrain_cache(tree):
    fn = _CACHE_SPEC_FN[0]
    if fn is None or tree is None:
        return tree

    def leaf(x):
        spec = fn(x.shape)
        if spec is None:
            return x
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:  # best-effort (see _constrain)
            return x

    return jax.tree.map(leaf, tree)


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    n: int                      # scanned group count
    kinds: tuple[str, ...]      # unrolled block kinds within a group


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------


def build_plan(cfg: ModelConfig) -> tuple[Segment, ...]:
    plan = _build_plan_base(cfg)
    if cfg.plan_override:
        over = dict(cfg.plan_override)
        plan = tuple(
            dataclasses.replace(s, n=over.get(s.name, s.n)) for s in plan
        )
    return plan


def _build_plan_base(cfg: ModelConfig) -> tuple[Segment, ...]:
    if cfg.family == "encdec":
        return (
            Segment("enc", cfg.enc_layers or cfg.n_layers, ("enc_block",)),
            Segment("dec", cfg.n_layers, ("dec_block",)),
        )
    if cfg.family == "ssm":  # xlstm
        per = cfg.slstm_every
        groups = cfg.n_layers // per
        return (Segment("xl", groups, ("mlstm",) * (per - 1) + ("slstm",)),)
    if cfg.family == "hybrid":  # zamba2
        per = cfg.attn_every
        groups = cfg.n_layers // per
        tail = cfg.n_layers - groups * per
        segs = [Segment("zb", groups, ("mamba",) * (per - 1) + ("shared_attn",))]
        if tail:
            segs.append(Segment("zt", tail, ("mamba",)))
        return tuple(segs)
    if cfg.family == "moe":
        segs = []
        if cfg.n_dense_layers:
            segs.append(Segment("dense", cfg.n_dense_layers, ("attn_mlp",)))
        segs.append(
            Segment("moe", cfg.n_layers - cfg.n_dense_layers, ("attn_moe",))
        )
        return tuple(segs)
    # dense (incl. vlm backbone)
    if cfg.local_ratio:
        per = cfg.local_ratio + 1
        groups = cfg.n_layers // per
        tail = cfg.n_layers - groups * per
        segs = [Segment("gl", groups, ("attn_local",) * cfg.local_ratio + ("attn_mlp",))]
        if tail:
            segs.append(Segment("gt", tail, ("attn_local",)))
        return tuple(segs)
    return (Segment("L", cfg.n_layers, ("attn_mlp",)),)


# ---------------------------------------------------------------------------
# block kind: tables
# ---------------------------------------------------------------------------


def _kind_table(kind: str, cfg: ModelConfig) -> Table:
    d = cfg.d_model
    norm1 = {"norm1": ((d,), ("embed",), "ones")}
    norm2 = {"norm2": ((d,), ("embed",), "ones")}
    if kind in ("attn_mlp", "attn_local"):
        a = attn.mla_table(cfg) if cfg.mla else attn.attn_table(cfg)
        return {**norm1, **prefix_table(a, "attn"), **norm2,
                **prefix_table(mlp_table(cfg), "mlp")}
    if kind == "attn_moe":
        a = attn.mla_table(cfg) if cfg.mla else attn.attn_table(cfg)
        return {**norm1, **prefix_table(a, "attn"), **norm2,
                **prefix_table(moe_mod.moe_table(cfg), "moe")}
    if kind == "mamba":
        return {**norm1, **prefix_table(ssm_mod.mamba_table(cfg), "ssm")}
    if kind == "shared_attn":
        # Marker only: parameters are the global shared block (see build_table).
        return {}
    if kind == "mlstm":
        return {**norm1, **prefix_table(xlstm_mod.mlstm_table(cfg), "mx")}
    if kind == "slstm":
        return {**norm1, **prefix_table(xlstm_mod.slstm_table(cfg), "sx")}
    if kind == "enc_block":
        return {
            "ln1_s": ((d,), ("embed",), "ones"), "ln1_b": ((d,), ("embed",), "zeros"),
            **prefix_table(attn.attn_table(cfg), "attn"),
            "ln2_s": ((d,), ("embed",), "ones"), "ln2_b": ((d,), ("embed",), "zeros"),
            **prefix_table(_whisper_mlp(cfg), "mlp"),
        }
    if kind == "dec_block":
        return {
            "ln1_s": ((d,), ("embed",), "ones"), "ln1_b": ((d,), ("embed",), "zeros"),
            **prefix_table(attn.attn_table(cfg), "attn"),
            "ln2_s": ((d,), ("embed",), "ones"), "ln2_b": ((d,), ("embed",), "zeros"),
            **prefix_table(attn.attn_table(cfg), "xattn"),
            "ln3_s": ((d,), ("embed",), "ones"), "ln3_b": ((d,), ("embed",), "zeros"),
            **prefix_table(_whisper_mlp(cfg), "mlp"),
        }
    raise ValueError(kind)


def _whisper_mlp(cfg: ModelConfig) -> Table:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w1": ((d, ff), ("embed", "mlp"), "normal"),
        "b1": ((ff,), ("mlp",), "zeros"),
        "w2": ((ff, d), ("mlp", "embed"), "normal"),
        "b2": ((d,), ("embed",), "zeros"),
    }


def build_table(cfg: ModelConfig) -> dict[str, Table]:
    """Full parameter table, grouped: {"segment:<name>:<j>": stacked table,
    "top": embeddings/head/final norm, "shared": weight-tied blocks}."""
    tables: dict[str, Table] = {}
    d = cfg.d_model
    top: Table = {
        "embed": ((cfg.vocab_size, d), ("vocab", "embed"), "embed"),
        "final_norm": ((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        top["head"] = ((d, cfg.vocab_size), ("embed", "vocab"), "normal")
    if cfg.family == "encdec":
        top["final_norm_b"] = ((d,), ("embed",), "zeros")
        top["enc_final_s"] = ((d,), ("embed",), "ones")
        top["enc_final_b"] = ((d,), ("embed",), "zeros")
    if cfg.mtp_depth:
        top["mtp/proj"] = ((2 * d, d), ("embed", "embed"), "normal")
        top["mtp/norm_h"] = ((d,), ("embed",), "ones")
        top["mtp/norm_e"] = ((d,), ("embed",), "ones")
    tables["top"] = top

    shared: Table = {}
    if cfg.family == "hybrid":
        shared.update(prefix_table(_kind_table("attn_mlp", cfg), "shared_attn"))
    if cfg.mtp_depth:
        shared.update(prefix_table(_kind_table("attn_mlp", cfg), "mtp_block"))
    if shared:
        tables["shared"] = shared

    for seg in build_plan(cfg):
        for j, kind in enumerate(seg.kinds):
            t = _kind_table(kind, cfg)
            if t:
                tables[f"segment:{seg.name}:{j}"] = stack_table(t, seg.n)
    return tables


def flat_table(cfg: ModelConfig) -> Table:
    out: Table = {}
    for group, t in build_table(cfg).items():
        out.update({f"{group}|{k}": v for k, v in t.items()})
    return out


def init_params(cfg: ModelConfig, key: Array) -> dict[str, Array]:
    return init_from_table(key, flat_table(cfg), dtype=jnp.dtype(cfg.dtype))


def param_shapes(cfg: ModelConfig) -> dict[str, jax.ShapeDtypeStruct]:
    return shapes_from_table(flat_table(cfg), dtype=jnp.dtype(cfg.dtype))


def param_specs(cfg: ModelConfig, rules: Mapping[str, Any]):
    return specs_from_table(flat_table(cfg), rules)


def _group_params(params: Mapping[str, Array], group: str) -> dict[str, Array]:
    pre = f"{group}|"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


# ---------------------------------------------------------------------------
# block forward dispatch
# ---------------------------------------------------------------------------


def _block_apply(
    kind: str,
    p: Mapping[str, Array],
    x: Array,
    cfg: ModelConfig,
    *,
    mode: str,                     # train | prefill | decode
    positions: Array | None,
    pos: Array | None,
    cache: Any,
    shared: Mapping[str, Array] | None,
    enc_out: Array | None = None,
):
    """Returns (x_out, new_cache, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if kind == "shared_attn":
        # weight-tied global attention block (zamba2)
        sp = {k[len("shared_attn/"):]: v for k, v in shared.items()
              if k.startswith("shared_attn/")}
        return _block_apply(
            "attn_mlp", sp, x, cfg, mode=mode, positions=positions, pos=pos,
            cache=cache, shared=None,
        )

    if kind in ("attn_mlp", "attn_local", "attn_moe"):
        window = cfg.window if kind == "attn_local" else 0
        h = rms_norm(x, p["norm1"])
        if mode == "decode":
            if cfg.mla:
                a_out, new_cache = attn.mla_decode(p, h, pos, cache, cfg, prefix="attn")
            else:
                a_out, new_cache = attn.gqa_decode(
                    p, h, pos, cache, cfg, prefix="attn", window=window
                )
        else:
            want_cache = mode == "prefill"
            if cfg.mla:
                r = attn.mla_forward(p, h, positions, cfg, prefix="attn",
                                     return_cache=want_cache)
            else:
                r = attn.gqa_forward(p, h, positions, cfg, prefix="attn",
                                     window=window, return_cache=want_cache)
            a_out, new_cache = (r if want_cache else (r, None))
        x = x + a_out
        h2 = rms_norm(x, p["norm2"])
        if kind == "attn_moe":
            # Decode batches are tiny: relax capacity towards dropless
            # (E/top_k ensures zero drops) — standard serving practice.
            cf = (
                min(4.0 * cfg.capacity_factor, cfg.n_experts / cfg.top_k)
                if mode == "decode" else None
            )
            m_out, aux = moe_mod.moe_forward(
                p, h2, cfg, prefix="moe", capacity_factor=cf
            )
        else:
            m_out, aux = gated_mlp(p, "mlp", h2), zero
        return x + m_out, new_cache, aux

    if kind == "mamba":
        h = rms_norm(x, p["norm1"])
        if mode == "decode":
            out, new_cache = ssm_mod.mamba_decode(p, h, cache, cfg, prefix="ssm")
        elif mode == "prefill":
            out, new_cache = ssm_mod.mamba_forward(
                p, h, cfg, prefix="ssm", return_cache=True
            )
        else:
            out, new_cache = ssm_mod.mamba_forward(p, h, cfg, prefix="ssm"), None
        return x + out, new_cache, zero

    if kind == "mlstm":
        h = rms_norm(x, p["norm1"])
        if mode == "decode":
            out, new_cache = xlstm_mod.mlstm_decode(p, h, cache, cfg, prefix="mx")
        elif mode == "prefill":
            out, new_cache = xlstm_mod.mlstm_forward(
                p, h, cfg, prefix="mx", return_cache=True
            )
        else:
            out, new_cache = xlstm_mod.mlstm_forward(p, h, cfg, prefix="mx"), None
        return x + out, new_cache, zero

    if kind == "slstm":
        h = rms_norm(x, p["norm1"])
        if mode == "decode":
            out, new_cache = xlstm_mod.slstm_decode(p, h, cache, cfg, prefix="sx")
        elif mode == "prefill":
            out, new_cache = xlstm_mod.slstm_forward(
                p, h, cfg, prefix="sx", return_cache=True
            )
        else:
            out, new_cache = xlstm_mod.slstm_forward(p, h, cfg, prefix="sx"), None
        return x + out, new_cache, zero

    if kind == "enc_block":
        h = layer_norm(x, p["ln1_s"], p["ln1_b"])
        a_out = attn.gqa_forward(p, h, positions, cfg, prefix="attn", causal=False)
        x = x + a_out
        h2 = layer_norm(x, p["ln2_s"], p["ln2_b"])
        m = jax.nn.gelu(h2 @ p["mlp/w1"] + p["mlp/b1"]) @ p["mlp/w2"] + p["mlp/b2"]
        return x + m, None, zero

    if kind == "dec_block":
        h = layer_norm(x, p["ln1_s"], p["ln1_b"])
        self_cache = cache[0] if cache is not None else None
        if mode == "decode":
            a_out, new_self = attn.gqa_decode(p, h, pos, self_cache, cfg, prefix="attn")
        else:
            want = mode == "prefill"
            r = attn.gqa_forward(p, h, positions, cfg, prefix="attn", return_cache=want)
            a_out, new_self = (r if want else (r, None))
        x = x + a_out
        h2 = layer_norm(x, p["ln2_s"], p["ln2_b"])
        # cross attention: k/v from encoder output (cached at prefill)
        if mode == "decode":
            xk, xv = cache[1]
            b = h2.shape[0]
            q = (h2 @ p["xattn/wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
            ctx = attn.decode_attention(
                q, xk, xv, jnp.asarray(xk.shape[1] - 1, jnp.int32)
            )
            a2 = ctx.reshape(b, 1, -1) @ p["xattn/wo"]
            new_cross = (xk, xv)
        else:
            b, sd, _ = h2.shape
            se = enc_out.shape[1]
            q = (h2 @ p["xattn/wq"]).reshape(b, sd, cfg.n_heads, cfg.hd)
            xk = (enc_out @ p["xattn/wk"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
            xv = (enc_out @ p["xattn/wv"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
            ctx = attn.causal_attention(q, xk, xv, q_chunk=cfg.q_chunk, causal=False)
            a2 = ctx.reshape(b, sd, -1) @ p["xattn/wo"]
            new_cross = (xk, xv) if mode == "prefill" else None
        x = x + a2
        h3 = layer_norm(x, p["ln3_s"], p["ln3_b"])
        m = jax.nn.gelu(h3 @ p["mlp/w1"] + p["mlp/b1"]) @ p["mlp/w2"] + p["mlp/b2"]
        new_cache = (new_self, new_cross) if mode != "train" else None
        return x + m, new_cache, zero

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# segment runner
# ---------------------------------------------------------------------------


def _run_segments(
    cfg: ModelConfig,
    params: Mapping[str, Array],
    x: Array,
    *,
    mode: str,
    positions: Array | None = None,
    pos: Array | None = None,
    caches: dict | None = None,
    segments: tuple[Segment, ...] | None = None,
    enc_out: Array | None = None,
    remat: bool = False,
):
    """Run the plan. Returns (x, new_caches, total_aux)."""
    shared = _group_params(params, "shared")
    plan = segments if segments is not None else build_plan(cfg)
    new_caches: dict = {}
    total_aux = jnp.zeros((), jnp.float32)

    for seg in plan:
        seg_params = []
        for j, kind in enumerate(seg.kinds):
            g = _group_params(params, f"segment:{seg.name}:{j}")
            seg_params.append(g)
        seg_cache = caches.get(seg.name) if caches else None

        def group_body(carry, xs, _kinds=seg.kinds):
            xx, aux = carry
            layer_params, layer_cache = xs
            out_cache = []
            for j, kind in enumerate(_kinds):
                cj = layer_cache[j] if layer_cache is not None else None
                xx, nc, a = _block_apply(
                    kind, layer_params[j], xx, cfg,
                    mode=mode, positions=positions, pos=pos, cache=cj,
                    shared=shared, enc_out=enc_out,
                )
                xx = _constrain(xx)
                if mode == "prefill":
                    nc = _constrain_cache(nc)
                out_cache.append(nc)
                aux = aux + a
            ys = tuple(out_cache) if mode != "train" else None
            return (xx, aux), ys

        body = group_body
        if remat and mode == "train":
            body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False,
            )
        xs = (tuple(seg_params), seg_cache)
        if cfg.unroll:
            # Flat-HLO path for roofline calibration (cost analysis counts
            # while bodies once; unrolled ops are counted exactly).
            carry = (x, total_aux)
            ys_list = []
            for i in range(seg.n):
                xs_i = jax.tree.map(lambda v: v[i], xs)
                carry, ys_i = body(carry, xs_i)
                ys_list.append(ys_i)
            (x, total_aux) = carry
            ys = (
                jax.tree.map(lambda *ls: jnp.stack(ls), *ys_list)
                if mode != "train" else None
            )
        else:
            (x, total_aux), ys = jax.lax.scan(body, (x, total_aux), xs, length=seg.n)
        if mode != "train":
            new_caches[seg.name] = ys
    return x, new_caches, total_aux


# ---------------------------------------------------------------------------
# top-level model API
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, tokens: Array, *, onehot: bool = False) -> Array:
    if onehot:
        # One-hot matmul lookup: a gather from a vocab-sharded table makes
        # the SPMD partitioner replicate it ("involuntary full
        # rematerialization", observed on the deepseek MTP path). The
        # contraction stays sharded and lands on the MXU; extra FLOPs are
        # 2·T·V·d / shards ≈ the head matmul (a few % of a train step).
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=jnp.dtype(cfg.dtype))
        e = oh @ params["top|embed"].astype(jnp.dtype(cfg.dtype))
    else:
        e = params["top|embed"][tokens]
    if cfg.family == "dense" and cfg.local_ratio:
        e = e * jnp.asarray(cfg.d_model ** 0.5, e.dtype)  # gemma convention
    return e.astype(jnp.dtype(cfg.dtype))


def _head(cfg: ModelConfig, params, x: Array) -> Array:
    x = rms_norm(x, params["top|final_norm"]) if cfg.family != "encdec" else x
    if cfg.tie_embeddings:
        return x @ params["top|embed"].T
    return x @ params["top|head"]


def forward(
    cfg: ModelConfig,
    params: Mapping[str, Array],
    batch: Mapping[str, Array],
    *,
    remat: bool = True,
):
    """Training forward. Returns (logits, aux_loss, hidden).

    batch keys by family:
      lm families:  tokens (B,S)
      vlm:          tokens (B,S_text), img_embeds (B,S_img,d)
      encdec:       frames (B,S_enc,d)  [stub frontend], tokens (B,S_dec)
    """
    if cfg.family == "encdec":
        return _encdec_forward(cfg, params, batch, remat=remat)

    oh = cfg.vocab_size >= 32768  # one-hot lookup for sharded-vocab tables
    if cfg.family == "vlm":
        img = batch["img_embeds"].astype(jnp.dtype(cfg.dtype))
        tok_e = _embed(cfg, params, batch["tokens"], onehot=oh)
        x = jnp.concatenate([img, tok_e], axis=1)
    else:
        x = _embed(cfg, params, batch["tokens"], onehot=oh)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _, aux = _run_segments(
        cfg, params, x, mode="train", positions=positions, remat=remat
    )
    logits = _head(cfg, params, x)
    return logits, aux, x


def loss_fn(
    cfg: ModelConfig,
    params: Mapping[str, Array],
    batch: Mapping[str, Array],
    *,
    aux_coef: float = 0.01,
    remat: bool = True,
):
    """Next-token CE (+ MoE balance aux + MTP)."""
    if cfg.family == "encdec":
        logits, aux, _ = _encdec_forward(cfg, params, batch, remat=remat)
        tok = batch["tokens"]
        loss = cross_entropy_loss(logits[:, :-1], tok[:, 1:])
        return loss + aux_coef * aux

    logits, aux, hidden = forward(cfg, params, batch, remat=remat)
    tok = batch["tokens"]
    if cfg.family == "vlm":
        # loss only over the text region
        s_img = batch["img_embeds"].shape[1]
        logits = logits[:, s_img:]
    loss = cross_entropy_loss(logits[:, :-1], tok[:, 1:])
    total = loss + aux_coef * aux
    if cfg.mtp_depth:
        total = total + 0.3 * _mtp_loss(cfg, params, hidden, tok)
    return total


def _mtp_loss(cfg: ModelConfig, params, hidden: Array, tokens: Array) -> Array:
    """DeepSeek-V3 MTP (depth 1): one extra block predicts token t+2 from
    [norm(h_t); norm(emb(tok_{t+1}))]."""
    h = hidden[:, :-2]                      # predict t+2 from context at t
    nxt = _embed(cfg, params, tokens[:, 1:-1], onehot=cfg.vocab_size >= 32768)
    hcat = jnp.concatenate(
        [rms_norm(h, params["top|mtp/norm_h"]), rms_norm(nxt, params["top|mtp/norm_e"])],
        axis=-1,
    )
    x = hcat @ params["top|mtp/proj"]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    shared = _group_params(params, "shared")
    mp = {k[len("mtp_block/"):]: v for k, v in shared.items()
          if k.startswith("mtp_block/")}
    x, _, _ = (_block_apply(
        "attn_mlp", mp, x, cfg, mode="train", positions=positions, pos=None,
        cache=None, shared=None,
    ))
    logits = _head(cfg, params, x)
    return cross_entropy_loss(logits, tokens[:, 2:])


def _encdec_forward(cfg: ModelConfig, params, batch, *, remat: bool):
    frames = batch["frames"].astype(jnp.dtype(cfg.dtype))  # (B,S_enc,d)
    b, se, d = frames.shape
    x = frames + sinusoidal_positions(se, d).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))
    plan = build_plan(cfg)
    enc_seg, dec_seg = plan[0], plan[1]
    x, _, _ = _run_segments(
        cfg, params, x, mode="train", positions=positions,
        segments=(enc_seg,), remat=remat,
    )
    enc_out = layer_norm(x, params["top|enc_final_s"], params["top|enc_final_b"])

    tok = batch["tokens"]
    sd = tok.shape[1]
    y = params["top|embed"][tok].astype(frames.dtype)
    y = y + sinusoidal_positions(sd, d).astype(frames.dtype)[None]
    dpos = jnp.broadcast_to(jnp.arange(sd, dtype=jnp.int32), (b, sd))
    y, _, aux = _run_segments(
        cfg, params, y, mode="train", positions=dpos,
        segments=(dec_seg,), enc_out=enc_out, remat=remat,
    )
    y = layer_norm(y, params["top|final_norm"], params["top|final_norm_b"])
    logits = y @ params["top|embed"].T
    return logits, aux, y


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, batch: Mapping[str, Array]):
    """Full forward over the prompt; returns (last-token logits, caches)."""
    if cfg.family == "encdec":
        frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
        b, se, d = frames.shape
        x = frames + sinusoidal_positions(se, d).astype(frames.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))
        enc_seg, dec_seg = build_plan(cfg)
        x, _, _ = _run_segments(cfg, params, x, mode="train",
                                positions=positions, segments=(enc_seg,))
        enc_out = layer_norm(x, params["top|enc_final_s"], params["top|enc_final_b"])
        tok = batch["tokens"]
        sd = tok.shape[1]
        y = params["top|embed"][tok].astype(frames.dtype)
        y = y + sinusoidal_positions(sd, d).astype(frames.dtype)[None]
        dpos = jnp.broadcast_to(jnp.arange(sd, dtype=jnp.int32), (b, sd))
        y, caches, _ = _run_segments(cfg, params, y, mode="prefill",
                                     positions=dpos, segments=(dec_seg,),
                                     enc_out=enc_out)
        y = layer_norm(y, params["top|final_norm"], params["top|final_norm_b"])
        logits = y[:, -1:] @ params["top|embed"].T
        return logits, caches

    if cfg.family == "vlm":
        img = batch["img_embeds"].astype(jnp.dtype(cfg.dtype))
        tok_e = _embed(cfg, params, batch["tokens"])
        x = jnp.concatenate([img, tok_e], axis=1)
    else:
        x = _embed(cfg, params, batch["tokens"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, caches, _ = _run_segments(cfg, params, x, mode="prefill", positions=positions)
    logits = _head(cfg, params, x[:, -1:])
    return logits, caches


def decode_step(cfg: ModelConfig, params, tokens: Array, pos: Array, caches):
    """One decode step. tokens (B,1); pos () int32; caches from prefill or
    init_cache. Returns (logits (B,1,V), new caches)."""
    if cfg.family == "encdec":
        d = cfg.d_model
        y = params["top|embed"][tokens].astype(jnp.dtype(cfg.dtype))
        ang = _sinusoid_at(pos, d).astype(y.dtype)  # sinusoidal position at pos
        y = y + ang[None, None, :]
        _, dec_seg = build_plan(cfg)
        y, caches2, _ = _run_segments(cfg, params, y, mode="decode", pos=pos,
                                      caches=caches, segments=(dec_seg,))
        y = layer_norm(y, params["top|final_norm"], params["top|final_norm_b"])
        return y @ params["top|embed"].T, caches2

    x = _embed(cfg, params, tokens)
    x, caches2, _ = _run_segments(cfg, params, x, mode="decode", pos=pos, caches=caches)
    return _head(cfg, params, x), caches2


def _sinusoid_at(pos: Array, d: int) -> Array:
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# cache construction (zeros or ShapeDtypeStructs for the dry-run)
# ---------------------------------------------------------------------------


def _kind_cache_spec(kind: str, cfg: ModelConfig, b: int, smax: int, enc_len: int):
    """Shape tuples for one block's cache (no leading segment axis)."""
    dt = jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    if kind in ("attn_mlp", "attn_local", "attn_moe", "shared_attn"):
        if cfg.mla and kind != "shared_attn":
            return (
                ((b, smax, cfg.kv_lora_rank), dt),
                ((b, smax, cfg.qk_rope_dim), dt),
            )
        t = min(cfg.window, smax) if (kind == "attn_local" and cfg.window) else smax
        return (((b, t, kv, hd), dt), ((b, t, kv, hd), dt))
    if kind == "mamba":
        d_in = cfg.ssm_expand * cfg.d_model
        heads = d_in // 64
        return (
            ((b, heads, cfg.ssm_state, 64), jnp.float32),
            ((b, cfg.ssm_conv - 1, d_in + 2 * cfg.ssm_state), dt),
        )
    if kind == "mlstm":
        d_in = 2 * cfg.d_model
        heads = cfg.n_heads
        hd2 = d_in // heads
        return (
            ((b, heads, hd2, hd2), jnp.float32),
            ((b, heads, hd2), jnp.float32),
            ((b, heads), jnp.float32),
            ((b, 3, d_in), dt),
        )
    if kind == "slstm":
        heads = cfg.n_heads
        hd2 = cfg.d_model // heads
        shp = ((b, heads, hd2), jnp.float32)
        return (shp, shp, shp, shp)
    if kind == "dec_block":
        self_c = (((b, smax, kv, hd), dt), ((b, smax, kv, hd), dt))
        cross_c = (((b, enc_len, kv, hd), dt), ((b, enc_len, kv, hd), dt))
        return (self_c, cross_c)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, b: int, smax: int, *, enc_len: int = 0,
               abstract: bool = False):
    """Zeroed (or abstract) cache pytree matching _run_segments layout."""
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (
        lambda s, d: jnp.zeros(s, d))

    def build(spec):
        if isinstance(spec, tuple) and spec and isinstance(spec[0], tuple) and (
            spec and isinstance(spec[0][0], tuple)
        ):
            # nested tuple (dec_block)
            return tuple(build(s) for s in spec)
        shape, dt = spec
        return mk(shape, dt)

    caches = {}
    plan = build_plan(cfg)
    if cfg.family == "encdec":
        plan = (plan[1],)  # only the decoder holds cache
    for seg in plan:
        blocks = []
        for kind in seg.kinds:
            spec = _kind_cache_spec(kind, cfg, b, smax, enc_len)
            if kind == "dec_block":
                entry = (tuple(build(s) for s in spec[0]),
                         tuple(build(s) for s in spec[1]))
            else:
                entry = tuple(build(s) for s in spec)
            # prepend segment axis
            entry = jax.tree.map(
                lambda l: (jax.ShapeDtypeStruct((seg.n,) + l.shape, l.dtype)
                           if abstract else jnp.zeros((seg.n,) + l.shape, l.dtype)),
                entry,
            )
            blocks.append(entry)
        caches[seg.name] = tuple(blocks)
    return caches
