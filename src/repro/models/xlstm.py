"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM (arXiv:2405.04517).

mLSTM — matrix memory C (B,H,hd,hd) with exponential input gates and
stabilizer state m. Training uses the chunkwise form: within a chunk of Q
steps the contribution weights exp(F_t - F_s + i_s) form a (Q,Q) lower-
triangular matrix computed with cumsum/cummax stabilization (all MXU
matmuls); across chunks only (C, n, m) is carried by a lax.scan. Decode is
the plain recurrence.

sLSTM — scalar memory per head with block-diagonal recurrent weights; the
recurrence on h_{t-1} makes it inherently sequential (the xLSTM paper says
as much), so training scans over time. The assigned xlstm-1.3b uses a 7:1
mLSTM:sLSTM ratio, so the sequential tax applies to 1/8 of layers.

Both blocks are residual pre-norm and carry their own up/down projections
(the assigned config has d_ff = 0: there are no separate MLP blocks).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Table, rms_norm

Array = jax.Array


def _mdims(cfg: ModelConfig):
    d_in = 2 * cfg.d_model
    heads = cfg.n_heads
    hd = d_in // heads
    return d_in, heads, hd


def mlstm_table(cfg: ModelConfig) -> Table:
    d = cfg.d_model
    d_in, heads, hd = _mdims(cfg)
    return {
        "up_x": ((d, d_in), ("embed", "mlp"), "normal"),
        "up_z": ((d, d_in), ("embed", "mlp"), "normal"),
        "conv_w": ((4, d_in), (None, "mlp"), "normal"),
        "conv_b": ((d_in,), ("mlp",), "zeros"),
        "wq": ((d_in, d_in), ("mlp", "heads"), "normal"),
        "wk": ((d_in, d_in), ("mlp", "heads"), "normal"),
        "wv": ((d_in, d_in), ("mlp", "heads"), "normal"),
        "wi": ((d_in, heads), ("mlp", None), "normal"),
        "wf": ((d_in, heads), ("mlp", None), "normal"),
        "fb": ((heads,), (None,), "ones"),   # forget bias > 0 at init
        "norm": ((d_in,), ("mlp",), "ones"),
        "down": ((d_in, d), ("mlp", "embed"), "normal"),
    }


def slstm_table(cfg: ModelConfig) -> Table:
    d = cfg.d_model
    heads = cfg.n_heads
    hd = d // heads
    ff = int(d * 4 / 3) // 64 * 64 * 2  # GLU pair, PF 4/3 (xLSTM paper)
    t: Table = {
        "wi": ((d, d), ("embed", "heads"), "normal"),
        "wf": ((d, d), ("embed", "heads"), "normal"),
        "wz": ((d, d), ("embed", "heads"), "normal"),
        "wo": ((d, d), ("embed", "heads"), "normal"),
        "ri": ((heads, hd, hd), (None, None, None), "normal"),
        "rf": ((heads, hd, hd), (None, None, None), "normal"),
        "rz": ((heads, hd, hd), (None, None, None), "normal"),
        "ro": ((heads, hd, hd), (None, None, None), "normal"),
        "fb": ((heads, hd), (None, None), "ones"),
        "norm": ((d,), ("embed",), "ones"),
        "ff_up": ((d, ff), ("embed", "mlp"), "normal"),
        "ff_down": ((ff // 2, d), ("mlp", "embed"), "normal"),
    }
    return t


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :], (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_chunked(q, k, v, log_i, log_f, chunk: int, cache=None):
    """q,k,v (B,S,H,hd); log_i/log_f (B,S,H). Returns y, (C, n, m) final.

    Stabilized chunkwise recurrence; see module docstring.
    """
    bsz, s_orig, h, hd = q.shape
    qn = min(chunk, s_orig)
    pad = (-s_orig) % qn
    if pad:
        # Padding steps: log_f=0 (no decay), log_i=-inf (no contribution);
        # k,v are zero so the state is exact; padded y rows sliced off below.
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(t, z4) for t in (q, k, v))
        log_i = jnp.pad(log_i, z3, constant_values=-1e30)
        log_f = jnp.pad(log_f, z3)
    s = s_orig + pad
    nc = s // qn
    f32 = jnp.float32

    qc = q.reshape(bsz, nc, qn, h, hd).astype(f32) * (hd ** -0.5)
    kc = k.reshape(bsz, nc, qn, h, hd).astype(f32)
    vc = v.reshape(bsz, nc, qn, h, hd).astype(f32)
    li = log_i.reshape(bsz, nc, qn, h).astype(f32)
    lf = log_f.reshape(bsz, nc, qn, h).astype(f32)

    fcum = jnp.cumsum(lf, axis=2)                # F_t inclusive
    a_s = li - fcum                              # a_s = i_s - F_s
    amax = jax.lax.cummax(a_s, axis=2)           # running max of a
    ftot = fcum[:, :, -1, :]                     # (B,nc,H)

    if cache is None:
        c0 = jnp.zeros((bsz, h, hd, hd), f32)
        n0 = jnp.zeros((bsz, h, hd), f32)
        m0 = jnp.full((bsz, h), -1e30, f32)
    else:
        c0, n0, m0 = cache

    def chunk_step(carry, inp):
        c_hat, n_hat, m_state = carry
        qq, kk, vv, li_, lf_, fcum_, a_, amax_, ftot_ = inp
        # (B,Q,H) row stabilizer: m_t = F_t + max(cummax_a_t, m_state - 0)
        m_row = fcum_ + jnp.maximum(amax_, m_state[:, None, :])
        # intra weights: exp(F_t - F_s + i_s - m_t) for s<=t
        wmat = jnp.exp(
            fcum_[:, :, None, :] + a_[:, None, :, :] - m_row[:, :, None, :]
        )  # (B,Q_t,Q_s,H)
        tri = jnp.tril(jnp.ones((qn, qn), bool))[None, :, :, None]
        wmat = jnp.where(tri, wmat, 0.0)
        scores = jnp.einsum("bqhd,bkhd->bqkh", qq, kk) * wmat
        num_intra = jnp.einsum("bqkh,bkhd->bqhd", scores, vv)
        den_intra = jnp.sum(scores, axis=2)  # (B,Q,H)
        # inter: exp(F_t + m_state - m_t) q C_hat
        w_in = jnp.exp(fcum_ + m_state[:, None, :] - m_row)  # (B,Q,H)
        num_inter = jnp.einsum("bqhd,bhde->bqhe", qq, c_hat) * w_in[..., None]
        den_inter = jnp.einsum("bqhd,bhd->bqh", qq, n_hat) * w_in
        num = num_intra + num_inter
        den = den_intra + den_inter
        y = num / jnp.maximum(jnp.abs(den)[..., None], jnp.exp(-m_row)[..., None] + 1e-6)
        # state update to end of chunk: m' = F_Q + max(max_s a_s, m_state)
        m_new = ftot_ + jnp.maximum(jnp.max(a_, axis=1), m_state)
        w_st = jnp.exp(ftot_[:, None, :] + a_ - m_new[:, None, :])  # (B,Q,H)
        c_hat = c_hat * jnp.exp(m_state + ftot_ - m_new)[:, :, None, None] + jnp.einsum(
            "bkh,bkhd,bkhe->bhde", w_st, kk, vv
        )
        n_hat = n_hat * jnp.exp(m_state + ftot_ - m_new)[:, :, None] + jnp.einsum(
            "bkh,bkhd->bhd", w_st, kk
        )
        return (c_hat, n_hat, m_new), y

    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (qc, kc, vc, li, lf, fcum, a_s, amax, ftot)
    )
    (c_f, n_f, m_f), ys = jax.lax.scan(chunk_step, (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, hd)[:, :s_orig]
    return y, (c_f, n_f, m_f)


def mlstm_forward(
    p: Mapping[str, Array], x: Array, cfg: ModelConfig, *, prefix: str = "",
    return_cache: bool = False,
):
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    bsz, s, _ = x.shape
    d_in, heads, hd = _mdims(cfg)
    xa = x @ p[f"{pre}up_x"]
    z = x @ p[f"{pre}up_z"]
    conv = jax.nn.silu(_causal_conv(xa, p[f"{pre}conv_w"], p[f"{pre}conv_b"]))
    q = (conv @ p[f"{pre}wq"]).reshape(bsz, s, heads, hd)
    k = (conv @ p[f"{pre}wk"]).reshape(bsz, s, heads, hd)
    v = (xa @ p[f"{pre}wv"]).reshape(bsz, s, heads, hd)
    log_i = (xa @ p[f"{pre}wi"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (xa @ p[f"{pre}wf"]).astype(jnp.float32) + p[f"{pre}fb"].astype(jnp.float32)
    )
    y, cache = _mlstm_chunked(q, k, v, log_i, log_f, cfg.ssm_chunk or 64)
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = rms_norm(y, p[f"{pre}norm"]) * jax.nn.silu(z)
    out = y @ p[f"{pre}down"]
    if return_cache:
        width = p[f"{pre}conv_w"].shape[0]
        tail = xa[:, -(width - 1) :, :]
        padn = (width - 1) - tail.shape[1]
        if padn > 0:
            tail = jnp.pad(tail, ((0, 0), (padn, 0), (0, 0)))
        return out, cache + (tail,)
    return out


def mlstm_decode(
    p: Mapping[str, Array], x: Array, cache, cfg: ModelConfig, *, prefix: str = "",
):
    """x (B,1,d); cache (C, n, m, conv_tail)."""
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    bsz = x.shape[0]
    d_in, heads, hd = _mdims(cfg)
    c_hat, n_hat, m_state, conv_tail = cache
    xa = x[:, 0, :] @ p[f"{pre}up_x"]
    z = x[:, 0, :] @ p[f"{pre}up_z"]
    w = p[f"{pre}conv_w"]
    hist = jnp.concatenate([conv_tail, xa[:, None, :]], axis=1)
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + p[f"{pre}conv_b"])
    new_tail = hist[:, 1:, :]
    q = (conv @ p[f"{pre}wq"]).reshape(bsz, heads, hd).astype(jnp.float32) * (hd ** -0.5)
    k = (conv @ p[f"{pre}wk"]).reshape(bsz, heads, hd).astype(jnp.float32)
    v = (xa @ p[f"{pre}wv"]).reshape(bsz, heads, hd).astype(jnp.float32)
    log_i = (xa @ p[f"{pre}wi"]).astype(jnp.float32)  # (B,H)
    log_f = jax.nn.log_sigmoid(
        (xa @ p[f"{pre}wf"]).astype(jnp.float32) + p[f"{pre}fb"].astype(jnp.float32)
    )
    m_new = jnp.maximum(log_f + m_state, log_i)
    fw = jnp.exp(log_f + m_state - m_new)
    iw = jnp.exp(log_i - m_new)
    c_hat = c_hat * fw[:, :, None, None] + iw[:, :, None, None] * k[:, :, :, None] * v[:, :, None, :]
    n_hat = n_hat * fw[:, :, None] + iw[:, :, None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_hat)
    den = jnp.einsum("bhd,bhd->bh", q, n_hat)
    y = num / jnp.maximum(jnp.abs(den)[..., None], jnp.exp(-m_new)[..., None] + 1e-6)
    y = y.reshape(bsz, d_in).astype(x.dtype)
    y = rms_norm(y, p[f"{pre}norm"]) * jax.nn.silu(z)
    out = (y @ p[f"{pre}down"])[:, None, :]
    return out, (c_hat, n_hat, m_new, new_tail)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _slstm_cell(p, pre, xg, h_prev, c_prev, n_prev, m_prev, heads, hd):
    """xg: dict of per-gate inputs at step t (B,H,hd)."""
    rec = lambda w, h: jnp.einsum("bhd,hde->bhe", h, w)
    i_pre = xg["i"] + rec(p[f"{pre}ri"], h_prev)
    f_pre = xg["f"] + rec(p[f"{pre}rf"], h_prev) + p[f"{pre}fb"]
    z_pre = xg["z"] + rec(p[f"{pre}rz"], h_prev)
    o_pre = xg["o"] + rec(p[f"{pre}ro"], h_prev)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m_prev, i_pre)
    iw = jnp.exp(i_pre - m_new)
    fw = jnp.exp(log_f + m_prev - m_new)
    c_new = fw * c_prev + iw * jnp.tanh(z_pre)
    n_new = fw * n_prev + iw
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_forward(
    p: Mapping[str, Array], x: Array, cfg: ModelConfig, *, prefix: str = "",
    return_cache: bool = False,
):
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    bsz, s, d = x.shape
    heads = cfg.n_heads
    hd = d // heads
    f32 = jnp.float32
    gates_in = {
        g: (x @ p[f"{pre}w{g}"]).reshape(bsz, s, heads, hd).astype(f32)
        for g in ("i", "f", "z", "o")
    }
    h0 = jnp.zeros((bsz, heads, hd), f32)
    c0 = jnp.zeros((bsz, heads, hd), f32)
    n0 = jnp.zeros((bsz, heads, hd), f32)
    m0 = jnp.full((bsz, heads, hd), -1e30, f32)

    def step(carry, inp):
        h, c, n, m = carry
        xg = {k: v for k, v in zip(("i", "f", "z", "o"), inp)}
        h, c, n, m = _slstm_cell(p, pre, xg, h, c, n, m, heads, hd)
        return (h, c, n, m), h

    xs = tuple(jnp.moveaxis(gates_in[g], 1, 0) for g in ("i", "f", "z", "o"))
    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    y = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, d).astype(x.dtype)
    y = rms_norm(y, p[f"{pre}norm"])
    up = y @ p[f"{pre}ff_up"]
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ p[f"{pre}ff_down"]
    if return_cache:
        return out, (h, c, n, m)
    return out


def slstm_decode(
    p: Mapping[str, Array], x: Array, cache, cfg: ModelConfig, *, prefix: str = "",
):
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    bsz, _, d = x.shape
    heads = cfg.n_heads
    hd = d // heads
    h, c, n, m = cache
    xg = {
        g: (x[:, 0, :] @ p[f"{pre}w{g}"]).reshape(bsz, heads, hd).astype(jnp.float32)
        for g in ("i", "f", "z", "o")
    }
    h, c, n, m = _slstm_cell(p, pre, xg, h, c, n, m, heads, hd)
    y = h.reshape(bsz, d).astype(x.dtype)
    y = rms_norm(y, p[f"{pre}norm"])
    up = y @ p[f"{pre}ff_up"]
    a, b = jnp.split(up, 2, axis=-1)
    out = ((jax.nn.gelu(a) * b) @ p[f"{pre}ff_down"])[:, None, :]
    return out, (h, c, n, m)
