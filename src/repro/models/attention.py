"""Attention: GQA (opt. qk-norm / bias / sliding window), MLA, KV caches.

Training / prefill use a *triangular q-chunk schedule*: a python-unrolled
loop over query chunks where each chunk attends only to its (statically
sliced) causal KV prefix — so HLO FLOPs are ~S(S+1)/2, not S^2, and the
(B,H,S,S) score matrix never materializes (peak score buffer is
(B,H,q_chunk,S)). Sliding-window layers additionally slice the KV prefix to
the window. This matters for the roofline numbers: masked-but-computed
attention would inflate HLO_FLOPs by up to 2x (see EXPERIMENTS.md SSPerf).

Decode reads a functional cache: full layers keep (B, Smax, KV, hd) K/V;
window layers keep a ring buffer (B, window, KV, hd) — RoPE is applied to K
*before* caching so ring rotation is position-free. MLA decode uses the
absorbed formulation over the compressed (B, S, kv_lora + rope) cache.
"""
from __future__ import annotations

import math
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Table, apply_rope, rms_norm, rope_freqs

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter tables
# ---------------------------------------------------------------------------


def attn_table(cfg: ModelConfig) -> Table:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    t: Table = {
        "wq": ((d, h * hd), ("embed", "heads"), "normal"),
        "wk": ((d, kv * hd), ("embed", "kv_heads"), "normal"),
        "wv": ((d, kv * hd), ("embed", "kv_heads"), "normal"),
        "wo": ((h * hd, d), ("heads", "embed"), "normal"),
    }
    if cfg.qkv_bias:
        t["bq"] = ((h * hd,), ("heads",), "zeros")
        t["bk"] = ((kv * hd,), ("kv_heads",), "zeros")
        t["bv"] = ((kv * hd,), ("kv_heads",), "zeros")
    if cfg.qk_norm:
        t["q_norm"] = ((hd,), (None,), "ones")
        t["k_norm"] = ((hd,), (None,), "ones")
    return t


def mla_table(cfg: ModelConfig) -> Table:
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": ((d, cfg.q_lora_rank), ("embed", None), "normal"),
        "q_norm": ((cfg.q_lora_rank,), (None,), "ones"),
        "wq_b": ((cfg.q_lora_rank, h * qk), (None, "heads"), "normal"),
        "wkv_a": ((d, cfg.kv_lora_rank + cfg.qk_rope_dim), ("embed", None), "normal"),
        "kv_norm": ((cfg.kv_lora_rank,), (None,), "ones"),
        "wkv_b": (
            (cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim)),
            (None, "heads"),
            "normal",
        ),
        "wo": ((h * cfg.v_head_dim, d), ("heads", "embed"), "normal"),
    }


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def _sdpa(q: Array, k: Array, v: Array, bias: Array | None, scale: float) -> Array:
    """q (B,Q,H,hd), k/v (B,T,KV,*) -> (B,Q,H,v_dim); GQA via head grouping."""
    b, qlen, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, qlen, kvh, rep, hd)
    scores = jnp.einsum(
        "bqgrd,btgd->bgrqt", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if bias is not None:
        scores = scores + bias  # bias broadcastable to (b,g,r,q,t)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bgrqt,btgv->bqgrv", p, v.astype(jnp.float32))
    return ctx.reshape(b, qlen, h, v.shape[-1]).astype(q.dtype)


def causal_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    q_chunk: int,
    window: int = 0,
    causal: bool = True,
    scale: float | None = None,
) -> Array:
    """Triangular q-chunk schedule (see module docstring). q,k,v aligned in
    time: position of q[:, i] == position of k[:, i]."""
    b, s, h, hd = q.shape
    scale = scale or (1.0 / math.sqrt(hd))
    qc = min(q_chunk, s)
    out = []
    for qs in range(0, s, qc):
        qe = min(qs + qc, s)
        qi = q[:, qs:qe]
        if causal:
            kv_end = qe
            kv_start = max(0, qs - window + 1) if window else 0
        else:
            kv_end, kv_start = s, 0
        ki = k[:, kv_start:kv_end]
        vi = v[:, kv_start:kv_end]
        qpos = jnp.arange(qs, qe)
        kpos = jnp.arange(kv_start, kv_end)
        mask = jnp.ones((qe - qs, kv_end - kv_start), jnp.bool_)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
        bias = jnp.where(mask, 0.0, NEG_INF)[None, None, None]
        out.append(_sdpa(qi, ki, vi, bias, scale))
    return jnp.concatenate(out, axis=1)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    pos: Array,
    *,
    window: int = 0,
    scale: float | None = None,
) -> Array:
    """One-token attention against the cache. pos: () int32 current position.

    Full layers: valid entries are idx <= pos. Window layers (ring buffer of
    size ``window``): all slots valid once pos >= window-1, else idx <= pos.
    """
    hd = q.shape[-1]
    scale = scale or (1.0 / math.sqrt(hd))
    t = k_cache.shape[1]
    idx = jnp.arange(t)
    if window:
        valid = jnp.where(pos >= window - 1, jnp.ones((t,), jnp.bool_), idx <= pos)
    else:
        valid = idx <= pos
    bias = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    return _sdpa(q, k_cache, v_cache, bias, scale)


# ---------------------------------------------------------------------------
# GQA block forward (train / prefill / decode)
# ---------------------------------------------------------------------------


def _project_qkv(p: Mapping[str, Array], pre: str, x: Array, cfg: ModelConfig):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p[f"{pre}wq"]
    k = x @ p[f"{pre}wk"]
    v = x @ p[f"{pre}wv"]
    if cfg.qkv_bias:
        q = q + p[f"{pre}bq"]
        k = k + p[f"{pre}bk"]
        v = v + p[f"{pre}bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p[f"{pre}q_norm"])
        k = rms_norm(k, p[f"{pre}k_norm"])
    return q, k, v


def gqa_forward(
    p: Mapping[str, Array],
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    *,
    prefix: str = "",
    window: int = 0,
    causal: bool = True,
    return_cache: bool = False,
):
    """Training/prefill attention. positions (B, S) int32 (RoPE)."""
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    q, k, v = _project_qkv(p, pre, x, cfg)
    cos, sin = rope_freqs(positions, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    ctx = causal_attention(
        q, k, v, q_chunk=cfg.q_chunk, window=window, causal=causal
    )
    out = ctx.reshape(x.shape[0], x.shape[1], -1) @ p[f"{pre}wo"]
    if not return_cache:
        return out
    if window:
        # Keep only the last `window` positions in ring order so decode can
        # continue writing at pos % window.
        s = k.shape[1]
        if s >= window:
            # keep[i] holds position (s-window+i); its ring slot is
            # (s-window+i) % window == (s+i) % window, i.e. a roll by s%window.
            keep = k[:, s - window :], v[:, s - window :]
            roll = s % window
            kc = jnp.roll(keep[0], roll, axis=1)
            vc = jnp.roll(keep[1], roll, axis=1)
        else:
            pad = window - s
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return out, (kc, vc)
    return out, (k, v)


def gqa_decode(
    p: Mapping[str, Array],
    x: Array,
    pos: Array,
    cache: tuple[Array, Array],
    cfg: ModelConfig,
    *,
    prefix: str = "",
    window: int = 0,
):
    """One-token decode. x (B, 1, d); pos () int32; cache (K, V)."""
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    q, k, v = _project_qkv(p, pre, x, cfg)
    posb = jnp.full((x.shape[0], 1), pos, jnp.int32)
    cos, sin = rope_freqs(posb, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k_cache, v_cache = cache
    slot = pos % window if window else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    ctx = decode_attention(q, k_cache, v_cache, pos, window=window)
    out = ctx.reshape(x.shape[0], 1, -1) @ p[f"{pre}wo"]
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)
# ---------------------------------------------------------------------------


def _mla_q(p, pre, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ql = rms_norm(x @ p[f"{pre}wq_a"], p[f"{pre}q_norm"])
    q = (ql @ p[f"{pre}wq_b"]).reshape(b, s, h, qk)
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = q[..., cfg.qk_nope_dim :]
    cos, sin = rope_freqs(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_kv_compress(p, pre, x, cfg: ModelConfig, positions):
    """-> c_kv normed (B,S,kv_lora), k_rope roped (B,S,1,rope)."""
    kv_a = x @ p[f"{pre}wkv_a"]
    c_kv = rms_norm(kv_a[..., : cfg.kv_lora_rank], p[f"{pre}kv_norm"])
    k_rope = kv_a[..., cfg.kv_lora_rank :][:, :, None, :]
    cos, sin = rope_freqs(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)
    return c_kv, k_rope


def mla_forward(
    p: Mapping[str, Array],
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    *,
    prefix: str = "",
    return_cache: bool = False,
):
    """Training/prefill MLA in the expanded (materialized k,v) form."""
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, pre, x, cfg, positions)
    c_kv, k_rope = _mla_kv_compress(p, pre, x, cfg, positions)
    kv = (c_kv @ p[f"{pre}wkv_b"]).reshape(
        b, s, h, cfg.qk_nope_dim + cfg.v_head_dim
    )
    k_nope = kv[..., : cfg.qk_nope_dim]
    v = kv[..., cfg.qk_nope_dim :]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))], axis=-1
    )
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    ctx = causal_attention(q, k, v, q_chunk=cfg.q_chunk, scale=scale)
    out = ctx.reshape(b, s, -1) @ p[f"{pre}wo"]
    if return_cache:
        return out, (c_kv, k_rope[:, :, 0, :])
    return out


def mla_decode(
    p: Mapping[str, Array],
    x: Array,
    pos: Array,
    cache: tuple[Array, Array],
    cfg: ModelConfig,
    *,
    prefix: str = "",
):
    """Absorbed-matrix MLA decode over the compressed cache.

    cache: (c_kv (B,Smax,kv_lora), k_rope (B,Smax,rope)).
    score_h = q_nope_h^T W_uk_h c + q_rope_h^T k_rope ;
    out_h   = W_uv_h (sum_t p_t c_t) — the per-head K/V are never expanded.
    """
    pre = f"{prefix}" if not prefix else f"{prefix}/"
    b = x.shape[0]
    h = cfg.n_heads
    posb = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, pre, x, cfg, posb)  # (B,1,H,*)
    c_new, krope_new = _mla_kv_compress(p, pre, x, cfg, posb)
    c_cache, r_cache = cache
    c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_new, pos, axis=1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(
        r_cache, krope_new[:, :, 0, :], pos, axis=1
    )
    wkv_b = p[f"{pre}wkv_b"].reshape(
        cfg.kv_lora_rank, h, cfg.qk_nope_dim + cfg.v_head_dim
    )
    w_uk = wkv_b[..., : cfg.qk_nope_dim]   # (kv_lora, H, nope)
    w_uv = wkv_b[..., cfg.qk_nope_dim :]   # (kv_lora, H, v)
    q_abs = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    scores = jnp.einsum("bqhk,btk->bhqt", q_abs, c_cache.astype(jnp.float32))
    scores += jnp.einsum(
        "bqhr,btr->bhqt", q_rope.astype(jnp.float32), r_cache.astype(jnp.float32)
    )
    scores *= 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    t = c_cache.shape[1]
    valid = jnp.arange(t) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bhqt,btk->bqhk", pr, c_cache.astype(jnp.float32))
    ctx = jnp.einsum("bqhk,khv->bqhv", ctx_c, w_uv.astype(jnp.float32))
    out = ctx.reshape(b, 1, -1).astype(x.dtype) @ p[f"{pre}wo"]
    return out, (c_cache, r_cache)
