"""Generic retry primitives: jittered exponential backoff + deadlines.

The clustering and serving paths share the same failure envelope — a
transient I/O edge (prefetch thread, checkpoint write, admission queue)
that should be retried a bounded number of times, with backoff, under an
overall wall-clock deadline. This module is the single implementation:

  * ``RetryPolicy``    — attempts / base / cap / multiplier / jitter / deadline;
  * ``backoff_delays`` — deterministic (seeded) jittered delay sequence, so
    chaos tests replay bit-identically;
  * ``Deadline``       — monotonic wall budget, injectable clock for tests;
  * ``retry_call``     — run a callable under a policy, raising ``RetryError``
    (chaining the last cause) once attempts or the deadline are exhausted.

Consumers: ``data/pipeline.py`` (prefetch restart), ``serving/engine.py``
(per-request deadlines), ``core/hpclust.py`` indirectly via the stream
checkpointer.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Iterator, Optional

import numpy as np


class RetryError(RuntimeError):
    """All attempts (or the deadline) exhausted; ``__cause__`` is the last error."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with capped, jittered exponential backoff."""

    max_attempts: int = 3         # total tries, including the first
    base_delay: float = 0.05      # seconds before the first retry
    max_delay: float = 2.0        # cap on any single delay
    multiplier: float = 2.0       # exponential growth factor
    jitter: float = 0.5           # +/- fraction of the nominal delay
    deadline_s: Optional[float] = None  # overall wall budget (None = unbounded)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay < 0 or self.max_delay < 0:
            raise ValueError("delays must be non-negative")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")


class Deadline:
    """Monotonic wall-clock budget. ``seconds=None`` never expires."""

    def __init__(self, seconds: Optional[float] = None, *,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._t0 = clock()
        self.seconds = seconds

    def remaining(self) -> float:
        if self.seconds is None:
            return math.inf
        return max(0.0, self.seconds - (self._clock() - self._t0))

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0.0


def backoff_delays(policy: RetryPolicy, *, seed: int = 0) -> Iterator[float]:
    """Infinite sequence of capped, jittered exponential delays.

    Jitter is drawn from a seeded generator so two runs with the same seed
    (e.g. a chaos test and its re-run) sleep the exact same schedule.
    """
    rng = np.random.default_rng(seed)
    nominal = policy.base_delay
    while True:
        j = 1.0 + policy.jitter * (2.0 * float(rng.random()) - 1.0)
        yield min(nominal * j, policy.max_delay)
        nominal = min(nominal * policy.multiplier, policy.max_delay)


def retry_call(
    fn: Callable[[], object],
    *,
    policy: RetryPolicy = RetryPolicy(),
    retry_on: tuple[type[BaseException], ...] = (Exception,),
    seed: int = 0,
    sleep: Callable[[float], None] = time.sleep,
    clock: Callable[[], float] = time.monotonic,
    on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
):
    """Call ``fn`` until it succeeds, a non-retryable error escapes, the
    attempt budget runs out, or the deadline expires.

    ``on_retry(attempt, error, delay)`` fires before each backoff sleep —
    the hook the pipeline uses to log producer restarts.
    """
    deadline = Deadline(policy.deadline_s, clock=clock)
    delays = backoff_delays(policy, seed=seed)
    last: Optional[BaseException] = None
    attempt = 0
    for attempt in range(1, policy.max_attempts + 1):
        try:
            return fn()
        except retry_on as e:
            last = e
            if attempt >= policy.max_attempts or deadline.expired:
                break
            delay = min(next(delays), max(deadline.remaining(), 0.0))
            if on_retry is not None:
                on_retry(attempt, e, delay)
            sleep(delay)
    raise RetryError(
        f"gave up after {attempt} attempt(s)"
        + ("" if not deadline.expired else " (deadline expired)")
    ) from last
