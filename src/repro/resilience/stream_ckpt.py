"""Checkpointing for ``HPClust.fit_stream``: WorkerState + stream cursor.

Layout reuses ``CheckpointManager`` verbatim (atomic tmp+rename writes,
sha256 integrity, retention), with the *window index* as the step number:

    <dir>/step_<windows_done>/leaves.npz   # flattened payload leaves
    <dir>/step_<windows_done>/meta.json

Payload pytree (dict keys sorted by tree_flatten, so the layout is stable):

    history         (rounds_so_far, W) f32  — per-round incumbent objectives
    sanitized_rows  int64                   — cumulative dropped/masked rows
    state           WorkerState             — centroids, best_obj,
                                              degenerate masks, PRNG keys

Because ``WorkerState.key`` rides along, a resumed stream replays the exact
per-worker sample draws the uninterrupted run would have made: by
keep-the-best monotonicity the resumed run's final objective can only
match-or-improve the incumbent it restarted from, and with an identical
window source it matches the uninterrupted run bit-for-bit.
"""
from __future__ import annotations

import os
from typing import TYPE_CHECKING, Any, NamedTuple, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager

if TYPE_CHECKING:  # repro.core imports this module — keep the cycle lazy
    from repro.core.strategies import HPClustConfig, WorkerState


class StreamCheckpoint(NamedTuple):
    windows_done: int
    state: Any                  # WorkerState; leaves are host numpy arrays
    history: np.ndarray         # (rounds_so_far, W) f32
    sanitized_rows: int


def _template(cfg: "HPClustConfig") -> dict:
    from repro.core.strategies import WorkerState

    # Only leaf COUNT and dtypes matter to CheckpointManager.restore; shapes
    # come from the stored arrays (this is what makes the template d-free).
    return {
        "history": np.zeros((0, cfg.workers), np.float32),
        "sanitized_rows": np.int64(0),
        "state": WorkerState(
            centroids=np.zeros((0,), np.float32),
            best_obj=np.zeros((0,), np.float32),
            degenerate=np.zeros((0,), np.bool_),
            key=np.zeros((0,), np.uint32),
        ),
    }


class StreamCheckpointer:
    """Periodic WorkerState checkpoints keyed by windows-consumed."""

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 async_save: bool = False):
        self.mgr = CheckpointManager(directory, keep=keep,
                                     async_save=async_save)

    def latest(self) -> Optional[int]:
        return self.mgr.latest_step()

    def save(
        self,
        windows_done: int,
        state: "WorkerState",
        history: np.ndarray,
        sanitized_rows: int,
        *,
        block: bool = True,
    ) -> None:
        tree = {
            "history": np.asarray(history, np.float32),
            "sanitized_rows": np.int64(sanitized_rows),
            "state": state,
        }
        self.mgr.save(windows_done, tree, block=block)

    def restore(
        self, cfg: "HPClustConfig", *, step: Optional[int] = None
    ) -> Optional[StreamCheckpoint]:
        """Latest (or given) checkpoint, or None when the directory is empty."""
        if step is None and self.mgr.latest_step() is None:
            return None
        windows_done, tree = self.mgr.restore(_template(cfg), step=step)
        return StreamCheckpoint(
            windows_done=int(windows_done),
            state=tree["state"],
            history=np.asarray(tree["history"], np.float32),
            sanitized_rows=int(tree["sanitized_rows"]),
        )
