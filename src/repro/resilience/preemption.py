"""SIGTERM-style preemption flag, shared by the trainer and ``fit_stream``.

A preempted TPU/GPU worker gets SIGTERM and a grace window; the correct
response everywhere in this codebase is the same: set a flag, finish the
current step/window, checkpoint, exit cleanly. ``PreemptionGuard`` is that
flag as a context manager, with

  * signal installation that tolerates non-main threads (tests, servers);
  * handler restoration on exit, so nested guards and pytest stay sane;
  * ``trigger()`` for deterministic chaos injection — the chaos harness
    preempts by calling it, no real signals needed.
"""
from __future__ import annotations

import signal
import threading
from typing import Iterable


class PreemptionGuard:
    """Latch that flips on SIGTERM (or an injected ``trigger()``)."""

    def __init__(self, signals: Iterable[int] = (signal.SIGTERM,)):
        self._signals = tuple(signals)
        self._prev: dict[int, object] = {}
        self._flag = threading.Event()
        self._installed = False

    # -- flag -----------------------------------------------------------------

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self, signum: int | None = None, frame=None) -> None:  # noqa: ARG002
        """Set the flag. Doubles as the signal handler and as the chaos hook."""
        self._flag.set()

    def reset(self) -> None:
        self._flag.clear()

    # -- signal plumbing ------------------------------------------------------

    def install(self) -> "PreemptionGuard":
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self.trigger)
            except ValueError:
                pass  # not on the main thread — trigger() still works
        self._installed = True
        return self

    def restore(self) -> None:
        for s, prev in self._prev.items():
            try:
                signal.signal(s, prev)
            except (ValueError, TypeError):
                pass
        self._prev.clear()
        self._installed = False

    def __enter__(self) -> "PreemptionGuard":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.restore()
