"""Checkpointing for the shard_map SPMD engine: ShardedState + round history.

The sharded twin of ``stream_ckpt``: layout reuses ``CheckpointManager``
verbatim (atomic tmp+rename writes, sha256 integrity, retention), with the
*window index* as the step number:

    <dir>/step_<windows_done>/leaves.npz   # flattened payload leaves
    <dir>/step_<windows_done>/meta.json

Payload pytree (dict keys sorted by tree_flatten, so the layout is stable):

    history   (rounds_so_far, W) f32  — per-round incumbent objectives
    state     ShardedState            — centroids, best_obj, degenerate,
                                        per-group PRNG keys, liveness mask,
                                        global round counter

Leaves are host-gathered full arrays (``CheckpointManager`` calls
``jax.device_get``), so a checkpoint written on one mesh restores onto any
other — the elastic contract. ``redistribute_state`` implements the
mesh-shrink rank rule: restoring W incumbents onto W' worker groups keeps
the objective-ranked best W' survivors (dead / non-finite incumbents rank
last), so a shrunk mesh loses only its worst searchers; a grown mesh clones
the ranked best with forked PRNG keys. Because every surviving group keeps
its own key and the global round counter rides along, a same-mesh resume
replays the uninterrupted run bit-for-bit, and any resume can only
match-or-improve by keep-the-best.
"""
from __future__ import annotations

import os
from typing import TYPE_CHECKING, Any, NamedTuple, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager

if TYPE_CHECKING:  # repro.core imports this package — keep the cycle lazy
    from repro.core.sharded import ShardedState


class ShardedStreamCheckpoint(NamedTuple):
    windows_done: int
    state: Any                  # ShardedState; leaves are host numpy arrays
    history: np.ndarray         # (rounds_so_far, W) f32


def _template() -> dict:
    from repro.core.sharded import ShardedState

    # Only leaf COUNT and dtypes matter to CheckpointManager.restore; shapes
    # come from the stored arrays (this is what makes the template d-free).
    return {
        "history": np.zeros((0, 0), np.float32),
        "state": ShardedState(
            centroids=np.zeros((0,), np.float32),
            best_obj=np.zeros((0,), np.float32),
            degenerate=np.zeros((0,), np.bool_),
            key=np.zeros((0,), np.uint32),
            alive=np.zeros((0,), np.bool_),
            rounds_done=np.int32(0),
        ),
    }


def redistribute_state(
    state: "ShardedState", history: np.ndarray, new_workers: int
) -> tuple["ShardedState", np.ndarray]:
    """Re-rank W checkpointed incumbents onto ``new_workers`` worker groups.

    Rank rule: ascending incumbent objective, with dead (liveness mask off)
    and non-finite incumbents ranked last — a shrunk mesh keeps the best
    survivors. A grown mesh cycles the ranking and forks each clone's PRNG
    key (``fold_in`` by destination slot) so replicas explore distinct
    streams. History columns follow their incumbents, so per-column
    monotonicity survives the reshuffle.
    """
    from repro.core.sharded import ShardedState

    c = np.asarray(state.centroids, np.float32)
    o = np.asarray(state.best_obj, np.float32)
    deg = np.asarray(state.degenerate, np.bool_)
    key = np.asarray(state.key, np.uint32)
    alive = np.asarray(state.alive, np.bool_)
    w = o.shape[0]
    if new_workers < 1:
        raise ValueError("new_workers must be positive")
    rank_obj = np.where(alive & np.isfinite(o), o, np.inf)
    order = np.argsort(rank_obj, kind="stable")
    src = order[np.arange(new_workers) % w]
    new_key = key[src].copy()
    if new_workers > w:
        import jax

        for j in range(w, new_workers):
            new_key[j] = np.asarray(jax.random.fold_in(key[src[j]], j))
    hist = np.asarray(history, np.float32)
    if hist.size:
        hist = hist[:, src]
    else:
        hist = np.zeros((0, new_workers), np.float32)
    return (
        ShardedState(
            centroids=c[src],
            best_obj=o[src],
            degenerate=deg[src],
            key=new_key,
            alive=alive[src],
            rounds_done=np.asarray(state.rounds_done, np.int32),
        ),
        hist,
    )


class ShardedStreamCheckpointer:
    """Periodic ShardedState checkpoints keyed by windows-consumed."""

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 async_save: bool = False):
        self.mgr = CheckpointManager(directory, keep=keep,
                                     async_save=async_save)

    def latest(self) -> Optional[int]:
        return self.mgr.latest_step()

    def save(
        self,
        windows_done: int,
        state: "ShardedState",
        history: np.ndarray,
        *,
        block: bool = True,
    ) -> None:
        tree = {
            "history": np.asarray(history, np.float32),
            "state": state,
        }
        self.mgr.save(windows_done, tree, block=block)

    def restore(
        self, *, step: Optional[int] = None
    ) -> Optional[ShardedStreamCheckpoint]:
        """Latest (or given) checkpoint, or None when the directory is empty."""
        if step is None and self.mgr.latest_step() is None:
            return None
        windows_done, tree = self.mgr.restore(_template(), step=step)
        return ShardedStreamCheckpoint(
            windows_done=int(windows_done),
            state=tree["state"],
            history=np.asarray(tree["history"], np.float32),
        )
