"""Stream-window sanitization: drop/mask non-finite rows before the device.

The paper's noise experiments (SS7.1) assume noise is *finite*; on real
streams a corrupted shard or overflowed feature produces NaN/Inf rows, and a
single such row drives every distance, objective and centroid to NaN —
poisoning all workers at once. Sanitization happens host-side, before
``jnp.asarray``, so the compiled program never sees a non-finite sample.

Masked rows are replaced (cyclically) by surviving rows rather than dropped:
window shape is part of the jit cache key, so shape-preserving repair keeps
one compiled program per window size instead of one per corruption pattern.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def sanitize_window(x: np.ndarray) -> tuple[Optional[np.ndarray], int]:
    """Replace non-finite rows of a (m, d) window with finite ones.

    Returns ``(clean_window, n_bad_rows)``. The clean window has the same
    shape and dtype as the input; bad rows are overwritten by surviving rows
    chosen cyclically (deterministic, seed-free). If *every* row is
    non-finite the window is unusable and ``(None, m)`` is returned — the
    caller should skip it and count it.
    """
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"expected a (m, d) window, got shape {x.shape}")
    bad = ~np.isfinite(x).all(axis=1)
    n_bad = int(bad.sum())
    if n_bad == 0:
        return x, 0
    good_idx = np.flatnonzero(~bad)
    if good_idx.size == 0:
        return None, n_bad
    out = np.array(x, copy=True)
    fill = good_idx[np.arange(n_bad) % good_idx.size]
    out[np.flatnonzero(bad)] = x[fill]
    return out, n_bad
