"""Deterministic fault injection (chaos harness) for the clustering stack.

Every injector is seeded and composable: wrap a window stream (or a state,
or a checkpoint manager) and the fault fires at an exact, reproducible
point. ``tests/test_resilience.py`` drives these end-to-end; nothing here
is imported by production code paths.

Catalogue:
  * ``corrupt_stream``   — NaN/Inf rows in chosen windows (window corruption)
  * ``crash_stream``     — raise ``ChaosError`` when a chosen window is pulled
  * ``preempt_stream``   — trip a ``PreemptionGuard`` before a chosen window
  * ``poison_state``     — NaN/-Inf a worker's incumbent objective/centroids
  * ``failing_source``   — one-shot producer deaths for prefetch threads
  * ``CrashingCheckpointManager`` — save-time crash at chosen steps
  * (step failures for the LM trainer already exist: ``Trainer(failure_at=...)``)

Sharded (collective) tier — drives ``repro.launch.elastic``:
  * ``drop_device_midstream``  — runner wrapper raising a simulated
                                 ``DeviceLostError`` at an exact invocation
  * ``poison_worker_group``    — non-finite incumbents on chosen worker-axis
                                 indices of a ``ShardedState``
  * ``desync_pod``             — one pod's incumbents revert to stale/poisoned
                                 (the hybrid2 cross-pod sync must repair it)
"""
from __future__ import annotations

import itertools
from typing import Callable, Iterable, Iterator, Mapping

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.strategies import WorkerState
from repro.resilience.preemption import PreemptionGuard


class ChaosError(RuntimeError):
    """An injected fault (never raised by production code)."""


_CORRUPT_VALUES = {"nan": np.nan, "inf": np.inf, "neginf": -np.inf}


def corrupt_stream(
    stream: Iterable[np.ndarray],
    *,
    at: Mapping[int, float],
    mode: str = "nan",
    seed: int = 0,
) -> Iterator[np.ndarray]:
    """Corrupt a fraction of rows in the windows named by ``at``.

    ``at`` maps window index -> fraction of rows overwritten with the mode
    value (``nan`` / ``inf`` / ``neginf``). Row choice is seeded.
    """
    if mode not in _CORRUPT_VALUES:
        raise ValueError(f"mode {mode!r} not in {sorted(_CORRUPT_VALUES)}")
    rng = np.random.default_rng(seed)
    for wi, w in enumerate(stream):
        frac = at.get(wi, 0.0)
        if frac > 0.0:
            w = np.array(w, copy=True)
            n_bad = max(1, int(round(len(w) * frac)))
            idx = rng.choice(len(w), size=min(n_bad, len(w)), replace=False)
            w[idx] = _CORRUPT_VALUES[mode]
        yield w


def corrupted_rows(at: Mapping[int, float], window: int) -> int:
    """Exact row count ``corrupt_stream`` injects (for metric assertions)."""
    return sum(
        min(max(1, int(round(window * frac))), window)
        for frac in at.values()
        if frac > 0.0
    )


def crash_stream(
    stream: Iterable[np.ndarray],
    *,
    at_window: int,
    exc_type: type[BaseException] = ChaosError,
) -> Iterator[np.ndarray]:
    """Raise when the consumer pulls window ``at_window`` (a mid-stream crash)."""
    for wi, w in enumerate(stream):
        if wi == at_window:
            raise exc_type(f"injected stream crash at window {wi}")
        yield w


def preempt_stream(
    stream: Iterable[np.ndarray],
    *,
    at_window: int,
    guard: PreemptionGuard,
) -> Iterator[np.ndarray]:
    """Trip ``guard`` just before yielding window ``at_window`` — the consumer
    sees the flag at its next check, mirroring a SIGTERM between windows."""
    for wi, w in enumerate(stream):
        if wi == at_window:
            guard.trigger()
        yield w


def poison_state(
    state: WorkerState,
    workers: Iterable[int],
    *,
    mode: str = "nan_obj",
) -> WorkerState:
    """Return a copy of ``state`` with the named workers' incumbents poisoned.

    Modes: ``nan_obj`` (NaN objective), ``neginf_obj`` (-inf objective — the
    nastier case: it *wins* any unguarded argmin), ``nan_centroids``.
    """
    c = np.array(state.centroids, np.float32, copy=True)
    o = np.array(state.best_obj, np.float32, copy=True)
    for w in workers:
        if mode == "nan_obj":
            o[w] = np.nan
        elif mode == "neginf_obj":
            o[w] = -np.inf
        elif mode == "nan_centroids":
            c[w] = np.nan
        else:
            raise ValueError(f"unknown poison mode {mode!r}")
    return WorkerState(jnp.asarray(c), jnp.asarray(o),
                       state.degenerate, state.key)


def failing_source(
    make_gen: Callable[[], Iterator],
    *,
    fail_at: Iterable[int],
    exc_type: type[BaseException] = ChaosError,
) -> Callable[[], Iterator]:
    """Wrap a generator factory so the stream dies at given *global* item
    counts. Failures are one-shot (consumed when fired), so a restarted
    producer makes progress — exactly a flaky-then-recovering data source.
    """
    pending = set(fail_at)
    counter = itertools.count()

    def factory() -> Iterator:
        for item in make_gen():
            i = next(counter)
            if i in pending:
                pending.discard(i)
                raise exc_type(f"injected producer death at item {i}")
            yield item

    return factory


class CrashingCheckpointManager(CheckpointManager):
    """CheckpointManager that dies inside ``_write`` at chosen steps.

    The crash fires before any byte is written; combined with the manager's
    tmp+atomic-rename protocol this models both "preempted mid-save" and
    "disk error on save" — the previous checkpoint must stay restorable.
    Crashes are one-shot, so a retried save succeeds.
    """

    def __init__(self, directory, *, crash_at_steps: Iterable[int], **kw):
        super().__init__(directory, **kw)
        self.crash_at_steps = set(crash_at_steps)

    def _write(self, step, paths, host):
        if step in self.crash_at_steps:
            self.crash_at_steps.discard(step)
            raise ChaosError(f"injected save crash at step {step}")
        super()._write(step, paths, host)


# ---------------------------------------------------------------------------
# sharded (collective) tier
# ---------------------------------------------------------------------------

def drop_device_midstream(*, at_call: int, lost_devices: Iterable[int]):
    """Runner-wrapper factory simulating device loss mid-stream.

    Returns a wrapper suitable for ``run_elastic_sharded(runner_wrapper=...)``:
    the ``at_call``-th invocation of the jitted runner (0-based, counted
    globally across mesh rebuilds — the engine re-wraps the recompiled
    runner with the same factory) raises ``DeviceLostError`` naming
    ``lost_devices``. One-shot and exact: the retry on the degraded mesh
    proceeds normally.
    """
    from repro.launch.elastic import DeviceLostError

    lost = tuple(lost_devices)
    calls = itertools.count()

    def wrapper(runner):
        def wrapped(*args, **kwargs):
            i = next(calls)
            if i == at_call:
                raise DeviceLostError(
                    f"injected device loss at runner call {i}", lost
                )
            return runner(*args, **kwargs)

        return wrapped

    return wrapper


def poison_worker_group(state, groups: Iterable[int], *, mode: str = "nan_obj"):
    """``poison_state`` for a ``ShardedState`` (keys/liveness/rounds intact).

    Modes mirror ``poison_state``: ``nan_obj``, ``neginf_obj``,
    ``nan_centroids``. The engine's in-round quarantine plus the liveness
    mask must keep the poison from ever owning a cooperative broadcast.
    """
    c = np.array(state.centroids, np.float32, copy=True)
    o = np.array(state.best_obj, np.float32, copy=True)
    for w in groups:
        if mode == "nan_obj":
            o[w] = np.nan
        elif mode == "neginf_obj":
            o[w] = -np.inf
        elif mode == "nan_centroids":
            c[w] = np.nan
        else:
            raise ValueError(f"unknown poison mode {mode!r}")
    return state._replace(centroids=jnp.asarray(c), best_obj=jnp.asarray(o))


def desync_pod(state, pod: int, *, pods: int, mode: str = "stale"):
    """Desynchronize one pod of a hybrid2 ``ShardedState``.

    Worker groups are laid out pod-major (``('pod', 'data')`` flattening), so
    pod ``p`` owns the contiguous slice of ``W // pods`` groups. ``stale``
    reverts the pod to the virgin all-degenerate state (as if it missed every
    sync since start); ``poison`` NaNs its objectives. The next cross-pod
    sync must repair the pod without regressing the other pods' incumbents.
    """
    c = np.array(state.centroids, np.float32, copy=True)
    o = np.array(state.best_obj, np.float32, copy=True)
    deg = np.array(state.degenerate, np.bool_, copy=True)
    w = o.shape[0]
    if pods < 1 or w % pods:
        raise ValueError(f"workers={w} not divisible into {pods} pods")
    per = w // pods
    sl = slice(pod * per, (pod + 1) * per)
    if mode == "stale":
        c[sl] = 0.0
        o[sl] = np.inf
        deg[sl] = True
    elif mode == "poison":
        o[sl] = np.nan
    else:
        raise ValueError(f"unknown desync mode {mode!r}")
    return state._replace(
        centroids=jnp.asarray(c),
        best_obj=jnp.asarray(o),
        degenerate=jnp.asarray(deg),
    )
