"""Supervised execution for the clustering stack: retry, preemption,
sanitization, stream checkpoints, and the chaos (fault-injection) harness.

See docs/resilience.md for the failure model and the injector catalogue.
"""
from repro.resilience.preemption import PreemptionGuard
from repro.resilience.retry import (
    Deadline,
    RetryError,
    RetryPolicy,
    backoff_delays,
    retry_call,
)
from repro.resilience.sanitize import sanitize_window
from repro.resilience.sharded_ckpt import (
    ShardedStreamCheckpoint,
    ShardedStreamCheckpointer,
    redistribute_state,
)
from repro.resilience.stream_ckpt import StreamCheckpoint, StreamCheckpointer

__all__ = [
    "Deadline",
    "PreemptionGuard",
    "RetryError",
    "RetryPolicy",
    "ShardedStreamCheckpoint",
    "ShardedStreamCheckpointer",
    "StreamCheckpoint",
    "StreamCheckpointer",
    "backoff_delays",
    "redistribute_state",
    "retry_call",
    "sanitize_window",
]
