"""starcoder2-3b [dense]: 30L, GQA 24H/2KV, RoPE. [arXiv:2402.19173; hf]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab_size=49152, rope_theta=1e5, grad_accum=8, q_chunk=256,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="starcoder2-3b-smoke", n_layers=4, d_model=48, n_heads=6,
    n_kv_heads=2, d_ff=96, vocab_size=512, q_chunk=32, dtype="float32",
)
