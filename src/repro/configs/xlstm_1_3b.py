"""xlstm-1.3b [ssm]: 48 blocks, 7:1 mLSTM:sLSTM, 4 heads, d_ff=0 (all
projections inside the blocks). [arXiv:2405.04517; unverified]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, slstm_every=8, ssm_chunk=256, grad_accum=8,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="xlstm-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, vocab_size=512, slstm_every=2, ssm_chunk=16,
    q_chunk=32, dtype="float32",
)
