"""zamba2-7b [hybrid]: 81 Mamba2 blocks (d_state 64) with a weight-shared
attention block every 6th position. Per-invocation LoRA deltas on the shared
block are omitted (DESIGN.md SS5). [arXiv:2411.15242; unverified]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128, attn_every=6,
    grad_accum=8,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512, ssm_state=16, ssm_chunk=16,
    attn_every=4, q_chunk=32, dtype="float32",
)
