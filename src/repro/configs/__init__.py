"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``smoke=True``
returns the reduced same-family config used by the CPU smoke tests. The
paper's own clustering deployments live in ``hpclust_prod``.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "gemma3-4b",
    "qwen3-0.6b",
    "qwen1.5-110b",
    "starcoder2-3b",
    "deepseek-v3-671b",
    "qwen3-moe-30b-a3b",
    "zamba2-7b",
    "xlstm-1.3b",
    "whisper-medium",
    "llava-next-34b",
)


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, smoke: bool = False):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    m = _module(name)
    return m.SMOKE if smoke else m.CONFIG
