"""llava-next-34b [vlm]: 60L dense backbone (GQA 56H/8KV); anyres vision
tiling is a STUB: input_specs() supplies 1024 precomputed patch embeddings
per example, concatenated before the backbone (DESIGN.md SS5).
[hf:llava-hf/llava-v1.6-*; unverified]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, rope_theta=5e6, img_tokens=1024, grad_accum=8,
    q_chunk=128,
    tie_embeddings=False, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="llava-smoke", n_layers=4, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=128, vocab_size=512, img_tokens=8, q_chunk=32,
    dtype="float32",
)
