"""qwen3-0.6b [dense]: 28L, GQA 16H/8KV, qk_norm. [hf:Qwen/Qwen3-*; hf]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072,
    vocab_size=151936, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-0.6b-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, q_chunk=32, dtype="float32",
)
