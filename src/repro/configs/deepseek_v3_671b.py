"""deepseek-v3-671b [moe]: 61L (3 dense + 58 MoE), MLA, 1 shared + 256
routed top-8 (sigmoid router, aux-free bias), MTP-1. [arXiv:2412.19437; hf].

d_ff=2048 is the per-expert (routed) width; dense layers use 4x d_ff_moe
x 2.25 = 18432 (published intermediate size). Optimizer: adafactor —
AdamW fp32 state for 671B exceeds 512x16 GB (DESIGN.md SS4)."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280,
    n_experts=256, n_shared_experts=1, top_k=8, d_ff_moe=2048,
    n_dense_layers=3, router_type="sigmoid", capacity_factor=1.25,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
    mtp_depth=1, tie_embeddings=False, optimizer="adafactor",
    grad_accum=8, grad_dtype="bfloat16",
    dtype="bfloat16", q_chunk=64,
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-v3-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=512, n_experts=8, top_k=2,
    d_ff_moe=32, n_dense_layers=1, capacity_factor=4.0, q_lora_rank=32, kv_lora_rank=16,
    qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16, q_chunk=32,
    dtype="float32",
)
