"""whisper-medium [audio enc-dec]: 24+24L, d=1024, 16H, d_ff=4096,
vocab 51865. Conv frontend is a STUB: input_specs() supplies precomputed
frame embeddings (B, S, d); decoder length = S // dec_ratio (DESIGN.md SS5).
[arXiv:2212.04356; unverified]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865, dec_ratio=8, grad_accum=4,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", n_layers=2, enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512, q_chunk=32,
    dtype="float32",
)
