"""gemma3-4b [dense]: 34L, GQA 8H/4KV, 5:1 local:global (window 1024),
vocab 262144. [hf:google/gemma-3-*; unverified]. head_dim = d/h = 320."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab_size=262144,
    local_ratio=5, window=1024, rope_theta=1e6, grad_accum=8,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-4b-smoke", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, window=16, q_chunk=32,
    dtype="float32",
)
