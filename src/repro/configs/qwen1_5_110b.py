"""qwen1.5-110b [dense]: 80L, GQA 64H/8KV, QKV bias. [hf:Qwen/Qwen1.5-*; hf]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    grad_accum=8, optimizer="adafactor", q_chunk=128,
    tie_embeddings=False, dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen1.5-110b-smoke", n_layers=4, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=192, vocab_size=512, q_chunk=32, dtype="float32",
)
