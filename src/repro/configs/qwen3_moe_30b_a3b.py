"""qwen3-moe-30b-a3b [moe]: 48L, GQA 32H/4KV, 128 experts top-8, qk_norm.
d_ff=768 is the per-expert width. [hf:Qwen/Qwen3-30B-A3B; hf]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=6144,
    vocab_size=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, d_ff_moe=768, n_dense_layers=0,
    router_type="softmax", capacity_factor=1.25, grad_accum=8,
    tie_embeddings=False, dtype="bfloat16", head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-moe-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, n_experts=8, top_k=2,
    d_ff_moe=32, capacity_factor=4.0, q_chunk=32, head_dim=16, dtype="float32",
)
