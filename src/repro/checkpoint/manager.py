"""Checkpointing: atomic, content-hashed, mesh-agnostic, async-capable.

Layout: <dir>/step_<N>/ containing ``leaves.npz`` (flattened pytree leaves,
host-gathered numpy) and ``meta.json`` (step, leaf paths, sha256 of the npz,
wall time). Writes go to a tmp dir + atomic rename, so a preempted writer
can never corrupt the latest checkpoint. Retention keeps the newest
``keep`` checkpoints.

Mesh-agnostic restore: leaves are full (unsharded) host arrays; ``restore``
re-shards them onto whatever mesh/sharding the *current* job uses — this is
what makes elastic restarts (different device counts) work; see
tests/test_checkpoint.py.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flat_with_paths(tree: PyTree):
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in kp) for kp, _ in leaves_with_paths]
    leaves = [v for _, v in leaves_with_paths]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: PyTree, *, block: bool = True) -> None:
        # Always join any in-flight async writer first: a blocking save racing
        # a background _write can interleave os.replace/_retain on the same
        # directories (two writers, one layout).
        self.wait()
        paths, leaves, _ = _flat_with_paths(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]

        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, paths, host), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, paths, host)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, paths: list[str], host: list[np.ndarray]):
        final = self.dir / f"step_{step:010d}"
        tmp = Path(tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=self.dir))
        try:
            npz_path = tmp / "leaves.npz"
            np.savez(npz_path, **{f"leaf_{i}": a for i, a in enumerate(host)})
            digest = hashlib.sha256(npz_path.read_bytes()).hexdigest()
            meta = {
                "step": step,
                "paths": paths,
                "sha256": digest,
                "time": time.time(),
                "n_leaves": len(host),
            }
            (tmp / "meta.json").write_text(json.dumps(meta))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic on POSIX
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._retain()

    def _retain(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- load ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "meta.json").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, *, step: int | None = None,
                shardings: PyTree | None = None,
                verify: bool = True) -> tuple[int, PyTree]:
        """Restore into the structure of ``template``. ``shardings`` (same
        structure or a single sharding) re-places leaves for the current
        mesh (elastic restart)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        meta = json.loads((d / "meta.json").read_text())
        if verify:
            digest = hashlib.sha256((d / "leaves.npz").read_bytes()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checkpoint {d} failed integrity check")
        with np.load(d / "leaves.npz") as z:
            host = [z[f"leaf_{i}"] for i in range(meta["n_leaves"])]
        t_leaves, treedef = jax.tree_util.tree_flatten(template)
        if len(t_leaves) != len(host):
            raise ValueError(
                f"checkpoint has {len(host)} leaves, template {len(t_leaves)}"
            )
        shard_leaves = None
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )[0]
            if len(shard_leaves) == 1:
                shard_leaves = shard_leaves * len(host)
        out = []
        for i, (a, t) in enumerate(zip(host, t_leaves)):
            arr = a.astype(t.dtype) if hasattr(t, "dtype") else a
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[i])
            out.append(arr)
        return step, jax.tree_util.tree_unflatten(treedef, out)
