"""Environment-gated performance flags for the streaming throughput engine.

One tiny module so every layer (core, kernels, data, launch, runtime) reads
the same switches the same way — and so docs/performance.md has a single
source of truth to point at. All flags are *opt-out*: the engine defaults to
its fastest safe configuration and an operator can disable any layer
independently to bisect a regression.

  REPRO_PREFETCH        "0" disables device prefetch everywhere; an integer
                        >= 1 sets the default double-buffer depth (default 2).
                        Per-estimator override: ``HPClust(prefetch=...)``.
  REPRO_DONATE          "0" disables buffer donation (state carries are then
                        copied every window/step, the pre-PR-10 behaviour).
  REPRO_AUTOTUNE        "0"/unset: kernel tile heuristics (default).
                        "1": consult the autotune cache, heuristics on miss.
                        "probe": consult; on miss, time candidate tiles and
                        persist the winner (see repro.kernels.autotune).
  REPRO_AUTOTUNE_CACHE  cache file path (default ~/.cache/repro/autotune.json).
  REPRO_COMPUTE_DTYPE   "bf16" switches the Pallas assign/lloyd kernels to
                        bf16 inputs with f32 accumulation (default "f32").

Flags are read per call (they only gate Python-level dispatch decisions, so
the cost is one dict lookup); dtype/autotune decisions become *static* jit
arguments so a mid-process flip can never alias a stale compile-cache entry.
"""
from __future__ import annotations

import os

_TRUE = ("1", "true", "on", "yes")
_FALSE = ("0", "false", "off", "no")


def donate_enabled() -> bool:
    """Buffer donation for the window/step state carries (default on)."""
    return os.environ.get("REPRO_DONATE", "1").lower() not in _FALSE


def prefetch_depth(override=None) -> int:
    """Device-prefetch double-buffer depth; 0 disables.

    ``override`` (``HPClust(prefetch=...)`` / ``fit_stream`` kwargs) wins over
    the environment: ``False``/``0`` -> 0, ``True``/``None`` -> env default.
    """
    if override is not None and override is not True:
        return max(0, int(override))
    raw = os.environ.get("REPRO_PREFETCH", "2").lower()
    if raw in _FALSE:
        return 0
    if raw in _TRUE:
        return 2
    try:
        return max(0, int(raw))
    except ValueError:
        return 2


def autotune_mode() -> str:
    """'off' | 'on' (consult cache) | 'probe' (consult + time + persist)."""
    raw = os.environ.get("REPRO_AUTOTUNE", "0").lower()
    if raw in _FALSE or raw == "":
        return "off"
    if raw == "probe":
        return "probe"
    return "on"


def autotune_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"),
    )


def compute_dtype(override: str | None = None) -> str:
    """Kernel compute dtype: 'f32' (default) or 'bf16' (f32 accumulation)."""
    dt = override or os.environ.get("REPRO_COMPUTE_DTYPE", "f32")
    dt = dt.lower()
    if dt in ("bf16", "bfloat16"):
        return "bf16"
    if dt in ("f32", "float32", ""):
        return "f32"
    raise ValueError(f"unknown compute dtype {dt!r} (want f32 or bf16)")
