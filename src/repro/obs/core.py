"""Dependency-free tracing + metrics core: spans, counters, gauges, histograms.

Contract (docs/observability.md):

  * **Zero overhead by default.** The module-level recorder in ``repro.obs``
    is ``None`` until ``configure()``/``set_recorder()`` is called; every
    instrumentation entry point early-returns the shared ``NULL_SPAN``
    singleton, so a disabled hot path costs one global read and allocates
    nothing (asserted by identity in tests/test_obs.py).
  * **Monotonic, injectable clock.** Durations come from ``time.monotonic``
    (never wall clock, which can step backwards under NTP); tests inject a
    deterministic fake so span durations are exact.
  * **Thread-safe.** Span stacks are thread-local (a prefetch worker's spans
    nest under its own roots, not the consumer's); metric updates are
    lock-protected; sink writes serialize on the sink's own lock.

No jax or numpy imports here: the core must be importable — and near-free —
from every module in the stack, including pure-host ones (data.pipeline,
serving.engine) and the analysis suite's no-execution constraint.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from typing import Any, Callable, Optional

Clock = Callable[[], float]

# Histograms keep raw observations up to this cap so the summarizer can
# compute exact quantiles; past the cap only count/sum/min/max keep updating
# (quantiles then describe the first _VALUES_CAP observations).
_VALUES_CAP = 8192

_RUN_IDS = itertools.count()


class NullSpan:
    """Shared do-nothing span, returned whenever tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "NullSpan":
        return self


NULL_SPAN = NullSpan()


class Span:
    """One timed region. Use as a context manager; nesting is tracked through
    the recorder's thread-local stack, so ``parent_id`` is assigned on entry
    without any caller bookkeeping."""

    __slots__ = (
        "name", "attrs", "span_id", "parent_id", "t0", "dur", "thread", "_rec"
    )

    def __init__(self, rec: "Recorder", name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.span_id = next(rec._ids)
        self.parent_id: Optional[int] = None
        self.t0 = 0.0
        self.dur = 0.0
        self.thread = threading.current_thread().name
        self._rec = rec

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = self._rec._stack()
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        self.t0 = self._rec.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.dur = self._rec.clock() - self.t0
        stack = self._rec._stack()
        if stack and stack[-1] is self:
            stack.pop()
        else:  # exited out of order (generator finalized late): best effort
            try:
                stack.remove(self)
            except ValueError:
                pass
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._rec._emit_span(self)
        return False

    def to_record(self) -> dict:
        return {
            "type": "span",
            "name": self.name,
            "ts": self.t0,
            "dur": self.dur,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "run": self._rec.run,
            "thread": self.thread,
            "attrs": self.attrs,
        }


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class Counter:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def snapshot(self) -> float:
        with self._lock:
            return self.value


class Gauge:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def snapshot(self) -> float:
        with self._lock:
            return self.value


class Histogram:
    __slots__ = ("name", "count", "total", "vmin", "vmax", "values", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.values: list[float] = []
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if len(self.values) < _VALUES_CAP:
                self.values.append(v)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "count": self.count,
                "sum": self.total,
                "min": self.vmin if self.count else None,
                "max": self.vmax if self.count else None,
                "values": list(self.values),
            }


def quantile(sorted_values: list, q: float) -> float:
    """Nearest-rank quantile over an already-sorted list (no numpy)."""
    if not sorted_values:
        raise ValueError("quantile of empty list")
    idx = min(len(sorted_values) - 1, max(0, round(q * (len(sorted_values) - 1))))
    return sorted_values[int(idx)]


class MetricRegistry:
    """Name -> metric map with lock-protected lazy creation."""

    def __init__(self):
        self._metrics: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)  # fast path: dict reads are GIL-atomic
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        with self._lock:
            metrics = list(self._metrics.values())
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in metrics:
            if isinstance(m, Counter):
                out["counters"][m.name] = m.snapshot()
            elif isinstance(m, Gauge):
                out["gauges"][m.name] = m.snapshot()
            else:
                out["histograms"][m.name] = m.snapshot()
        return out


# ---------------------------------------------------------------------------
# recorder
# ---------------------------------------------------------------------------


class Recorder:
    """Active telemetry collector: spans + events stream to the sinks as they
    close; metrics accumulate in the registry and are emitted as one
    ``{"type": "metrics"}`` snapshot record on ``flush()``/``close()``.

    ``sync_kernels=True`` makes the kernel-dispatch spans in
    ``repro.kernels.ops`` block until the device result is ready, trading a
    pipeline bubble for true execution timing (off by default — async
    dispatch means a kernel span normally measures dispatch cost only).
    """

    def __init__(
        self,
        sinks: tuple = (),
        *,
        clock: Clock = time.monotonic,
        sync_kernels: bool = False,
    ):
        self.clock = clock
        self.sinks = list(sinks)
        self.metrics = MetricRegistry()
        self.sync_kernels = sync_kernels
        # Span ids are only unique within one recorder; the run token keys
        # them globally so appended traces from several CLI invocations (or
        # several recorders in one test process) never cross-link.
        self.run = f"{os.getpid():x}.{next(_RUN_IDS)}"
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- spans ---------------------------------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def current_span(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    def _emit_span(self, span: Span) -> None:
        self._write(span.to_record())

    # -- events --------------------------------------------------------------

    def event(self, name: str, **attrs) -> None:
        self._write(
            {"type": "event", "name": name, "ts": self.clock(),
             "run": self.run, "attrs": attrs}
        )

    # -- metrics -------------------------------------------------------------

    def inc(self, name: str, n: float = 1.0) -> None:
        self.metrics.counter(name).add(n)

    def gauge(self, name: str, v: float) -> None:
        self.metrics.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.metrics.histogram(name).observe(v)

    # -- lifecycle -----------------------------------------------------------

    def _write(self, record: dict) -> None:
        for sink in self.sinks:
            sink.write(record)

    def flush(self) -> None:
        self._write(
            {"type": "metrics", "ts": self.clock(), **self.metrics.snapshot()}
        )
        for sink in self.sinks:
            sink.flush()

    def close(self) -> None:
        self.flush()
        for sink in self.sinks:
            sink.close()
