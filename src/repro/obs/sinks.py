"""Trace sinks: JSONL event stream + Prometheus-style text snapshot.

``JsonlSink`` is the durable format (one JSON object per line, consumed by
``python -m repro.obs summarize``); ``ListSink`` keeps records in memory for
tests; ``prometheus_text`` renders a registry snapshot in the Prometheus
text exposition format for scrape-style export.
"""
from __future__ import annotations

import json
import re
import threading
from typing import IO, Optional

from repro.obs.core import MetricRegistry, quantile

_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


class ListSink:
    """In-memory sink (tests, programmatic inspection)."""

    def __init__(self):
        self.records: list[dict] = []
        self._lock = threading.Lock()

    def write(self, record: dict) -> None:
        with self._lock:
            self.records.append(record)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


def _json_default(o):
    # Last-resort encoder so an odd attr (numpy scalar, Path) can't kill the
    # trace mid-run; numeric-looking objects keep their value.
    try:
        return float(o)
    except (TypeError, ValueError):
        return repr(o)


class JsonlSink:
    """Append-mode JSONL writer — successive traced CLIs accumulate into one
    trace file; line-buffered so a crash loses at most the current record."""

    def __init__(self, path: str, *, mode: str = "a"):
        self.path = path
        self._fh: Optional[IO[str]] = open(path, mode, buffering=1)
        self._lock = threading.Lock()

    def write(self, record: dict) -> None:
        line = json.dumps(record, separators=(",", ":"), default=_json_default)
        with self._lock:
            if self._fh is not None:
                self._fh.write(line + "\n")

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def _prom_name(name: str) -> str:
    return "repro_" + _PROM_BAD.sub("_", name)


def prometheus_text(registry: MetricRegistry) -> str:
    """Render a metrics snapshot in the Prometheus text format.

    Histograms export ``_count``/``_sum`` plus nearest-rank quantile gauges
    (summary-style) computed from the retained observations.
    """
    snap = registry.snapshot()
    lines: list[str] = []
    for name, v in sorted(snap["counters"].items()):
        p = _prom_name(name)
        lines += [f"# TYPE {p} counter", f"{p} {v:g}"]
    for name, v in sorted(snap["gauges"].items()):
        p = _prom_name(name)
        lines += [f"# TYPE {p} gauge", f"{p} {v:g}"]
    for name, h in sorted(snap["histograms"].items()):
        p = _prom_name(name)
        lines += [
            f"# TYPE {p} summary",
            f"{p}_count {h['count']}",
            f"{p}_sum {h['sum']:g}",
        ]
        values = sorted(h["values"])
        if values:
            for q in (0.5, 0.95, 0.99):
                lines.append(f'{p}{{quantile="{q}"}} {quantile(values, q):g}')
    return "\n".join(lines) + "\n"
