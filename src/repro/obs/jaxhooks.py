"""JAX-aware observability hooks.

Everything here degrades to a no-op when jax (or the specific profiler API)
is unavailable, so importing this module never adds a hard dependency beyond
what the instrumented code already has. Host-side spans live in
``repro.obs.core``; these hooks cover the *device* side:

  * ``named_scope``      — names a traced region so it survives into HLO
    metadata and XLA profiles (usable inside jit/vmap/scan bodies);
  * ``trace_annotation`` — host-thread annotation visible in a
    ``jax.profiler`` timeline (NOT usable inside traced code);
  * ``profiler_session`` — wrap a region in a jax.profiler trace dump;
  * ``device_memory_stats`` / ``sample_device_memory`` — per-device memory
    gauges where the backend exposes them (TPU does; CPU returns nothing).
"""
from __future__ import annotations

import contextlib
from typing import ContextManager, Iterator

try:  # pragma: no cover - exercised implicitly by every traced test
    import jax
except Exception:  # noqa: BLE001 — analysis-only hosts may lack jax entirely
    jax = None  # type: ignore[assignment]


def named_scope(name: str) -> ContextManager:
    """``jax.named_scope`` when available, else a null context."""
    if jax is not None and hasattr(jax, "named_scope"):
        return jax.named_scope(name)
    return contextlib.nullcontext()


def trace_annotation(name: str) -> ContextManager:
    """``jax.profiler.TraceAnnotation`` when available, else a null context."""
    prof = getattr(jax, "profiler", None) if jax is not None else None
    cls = getattr(prof, "TraceAnnotation", None)
    if cls is not None:
        return cls(name)
    return contextlib.nullcontext()


@contextlib.contextmanager
def profiler_session(logdir: str) -> Iterator[None]:
    """Run a ``jax.profiler`` trace around the with-body, dumping to
    ``logdir`` (TensorBoard/XProf format). No-op without the API."""
    prof = getattr(jax, "profiler", None) if jax is not None else None
    if prof is None or not hasattr(prof, "start_trace"):
        yield
        return
    prof.start_trace(logdir)
    try:
        yield
    finally:
        prof.stop_trace()


def device_memory_stats() -> dict[str, dict[str, int]]:
    """Per-device ``memory_stats()`` where the backend exposes it.

    Returns ``{device: {stat: bytes}}``; empty on backends without the API
    (host CPU) — callers must treat absence as "unknown", not zero.
    """
    if jax is None:
        return {}
    out: dict[str, dict[str, int]] = {}
    for dev in jax.local_devices():
        try:
            stats = dev.memory_stats()
        except Exception:  # noqa: BLE001 — per-device API is best-effort
            stats = None
        if stats:
            out[str(dev)] = {
                k: int(v) for k, v in stats.items()
                if isinstance(v, (int, float))
            }
    return out


def sample_device_memory(recorder) -> None:
    """Record ``bytes_in_use`` per device as gauges on ``recorder``."""
    if recorder is None:
        return
    for dev, stats in device_memory_stats().items():
        used = stats.get("bytes_in_use")
        if used is not None:
            recorder.gauge(f"device.bytes_in_use.{dev}", used)
