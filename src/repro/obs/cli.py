"""``python -m repro.obs`` — make a JSONL trace explainable after the fact.

Subcommands:
  summarize TRACE   span tree (total/self time, call counts), per-round
                    objective descent, metric rollups with p50/p95/p99.
  prom TRACE        last metrics snapshot in Prometheus text format.

Exit codes: 0 ok, 1 empty or unparseable trace, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from repro.obs.core import quantile


def load_trace(path: str) -> tuple[list, list, dict]:
    """Parse a JSONL trace into (spans, events, merged-last metrics)."""
    spans: list[dict] = []
    events: list[dict] = []
    metrics: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: invalid JSON: {e}") from e
            kind = rec.get("type")
            if kind == "span":
                spans.append(rec)
            elif kind == "event":
                events.append(rec)
            elif kind == "metrics":
                # Merge: later snapshots win per metric; histograms from
                # different runs appended to one file keep the later one.
                for fam in ("counters", "gauges", "histograms"):
                    metrics[fam].update(rec.get(fam, {}))
    return spans, events, metrics


# ---------------------------------------------------------------------------
# span tree
# ---------------------------------------------------------------------------


def _span_key(rec: dict, sid) -> tuple:
    return (rec.get("run", ""), sid)


def build_tree(spans: list[dict]):
    """Returns (roots, children) keyed by (run, span_id)."""
    by_id = {_span_key(s, s["span_id"]): s for s in spans}
    children: dict[tuple, list] = defaultdict(list)
    roots: list[dict] = []
    for s in spans:
        pid = s.get("parent_id")
        pkey = _span_key(s, pid)
        if pid is not None and pkey in by_id:
            children[pkey].append(s)
        else:
            roots.append(s)
    return roots, children


def _aggregate(nodes: list[dict], children: dict) -> list[dict]:
    """Group sibling spans by name: count, total, self, nested groups."""
    groups: dict[str, dict] = {}
    for s in nodes:
        g = groups.setdefault(
            s["name"], {"name": s["name"], "count": 0, "total": 0.0,
                        "self": 0.0, "kids": []}
        )
        kids = children.get(_span_key(s, s["span_id"]), [])
        g["count"] += 1
        g["total"] += s["dur"]
        g["self"] += s["dur"] - sum(k["dur"] for k in kids)
        g["kids"].extend(kids)
    out = []
    for g in sorted(groups.values(), key=lambda g: -g["total"]):
        g["children"] = _aggregate(g.pop("kids"), children)
        out.append(g)
    return out


def _fmt_s(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:8.3f}s "
    if seconds >= 1e-3:
        return f"{seconds * 1e3:8.2f}ms"
    return f"{seconds * 1e6:8.1f}us"


def print_span_tree(spans: list[dict], out=sys.stdout) -> None:
    roots, children = build_tree(spans)
    print("span tree (total / self / count):", file=out)

    def walk(groups: list[dict], depth: int) -> None:
        for g in groups:
            name = "  " * depth + g["name"]
            print(
                f"  {name:<44s} {_fmt_s(g['total'])} {_fmt_s(g['self'])}"
                f"  x{g['count']}",
                file=out,
            )
            walk(g["children"], depth + 1)

    walk(_aggregate(roots, children), 0)


# ---------------------------------------------------------------------------
# rounds + metrics
# ---------------------------------------------------------------------------


def print_rounds(events: list[dict], out=sys.stdout, limit: int = 48) -> None:
    rounds = [e for e in events if e["name"] == "hpclust.round"]
    if not rounds:
        return
    print("per-round objective (hpclust.round events):", file=out)
    shown = rounds if len(rounds) <= limit else rounds[:limit]
    for e in shown:
        a = e.get("attrs", {})
        where = f"window {a['window']} " if a.get("window") is not None else ""
        print(
            f"  {where}round {a.get('round', '?'):>3}: "
            f"best={a.get('best_obj', float('nan')):.6g} "
            f"accepted={a.get('accepted', '?')} "
            f"quarantined={a.get('quarantined', 0)}",
            file=out,
        )
    if len(rounds) > limit:
        print(f"  ... ({len(rounds) - limit} more rounds)", file=out)
    objs = [e["attrs"]["best_obj"] for e in rounds
            if "best_obj" in e.get("attrs", {})]
    if objs:
        finite = [o for o in objs if o == o and o != float("inf")]
        monotone = all(b <= a * (1 + 1e-6) for a, b in zip(objs, objs[1:]))
        print(
            f"  descent: first={objs[0]:.6g} last={objs[-1]:.6g} "
            f"best={min(finite):.6g} monotone={monotone}"
            if finite else "  descent: no finite objectives",
            file=out,
        )


def print_metrics(metrics: dict, out=sys.stdout) -> None:
    if not any(metrics.values()):
        return
    print("metrics:", file=out)
    for name, v in sorted(metrics["counters"].items()):
        print(f"  counter    {name:<40s} {v:g}", file=out)
    for name, v in sorted(metrics["gauges"].items()):
        print(f"  gauge      {name:<40s} {v:g}", file=out)
    for name, h in sorted(metrics["histograms"].items()):
        values = sorted(h.get("values", []))
        count = h.get("count", 0)
        mean = (h.get("sum", 0.0) / count) if count else float("nan")
        qtxt = ""
        if values:
            qtxt = (
                f" p50={quantile(values, 0.5):.6g}"
                f" p95={quantile(values, 0.95):.6g}"
                f" p99={quantile(values, 0.99):.6g}"
            )
        print(
            f"  histogram  {name:<40s} count={count} mean={mean:.6g}{qtxt}",
            file=out,
        )


def print_degraded_banner(events: list[dict], out=sys.stdout) -> None:
    """Loud banner when the run survived a degraded-mesh recovery.

    ``resilience.mesh_degraded`` marks lost devices + mesh rebuild;
    ``sharded.resumed`` marks the checkpoint restore that followed.
    """
    degraded = [e for e in events if e["name"] == "resilience.mesh_degraded"]
    if not degraded:
        return
    resumed = [e for e in events if e["name"] == "sharded.resumed"]
    print("!" * 64, file=out)
    print(f"!! DEGRADED MESH: {len(degraded)} recovery(ies) during this run",
          file=out)
    for e in degraded:
        a = e.get("attrs", {})
        print(
            f"!!   lost {a.get('lost_devices', '?')} device(s) "
            f"(total excluded {a.get('excluded_total', '?')}) -> "
            f"mesh {a.get('mesh_shape', '?')}, "
            f"{a.get('workers', '?')} worker group(s)",
            file=out,
        )
    for e in resumed:
        a = e.get("attrs", {})
        print(
            f"!!   resumed from checkpoint at window "
            f"{a.get('windows_done', '?')} onto "
            f"{a.get('workers', '?')} worker group(s)",
            file=out,
        )
    print("!" * 64, file=out)


def print_events(events: list[dict], out=sys.stdout) -> None:
    other = [e for e in events if e["name"] != "hpclust.round"]
    if not other:
        return
    counts: dict[str, int] = defaultdict(int)
    for e in other:
        counts[e["name"]] += 1
    print("events:", file=out)
    for name, n in sorted(counts.items()):
        print(f"  {name:<46s} x{n}", file=out)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def summarize(path: str, out=sys.stdout) -> int:
    try:
        spans, events, metrics = load_trace(path)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    if not spans and not events and not any(metrics.values()):
        print(f"error: {path} holds no trace records", file=sys.stderr)
        return 1
    print(f"trace {path}: {len(spans)} span(s), {len(events)} event(s)",
          file=out)
    print_degraded_banner(events, out)
    if spans:
        print_span_tree(spans, out)
    print_rounds(events, out)
    print_metrics(metrics, out)
    print_events(events, out)
    return 0


def prom(path: str, out=sys.stdout) -> int:
    """Re-render the trace's last metrics snapshot as Prometheus text."""
    from repro.obs.core import MetricRegistry

    try:
        _, _, metrics = load_trace(path)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    reg = MetricRegistry()
    for name, v in metrics["counters"].items():
        reg.counter(name).add(v)
    for name, v in metrics["gauges"].items():
        reg.gauge(name).set(v)
    for name, h in metrics["histograms"].items():
        hist = reg.histogram(name)
        for v in h.get("values", []):
            hist.observe(v)
        # Preserve count/sum beyond the retained values.
        hist.count = h.get("count", hist.count)
        hist.total = h.get("sum", hist.total)
    from repro.obs.sinks import prometheus_text

    out.write(prometheus_text(reg))
    return 0


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a repro.obs JSONL trace.",
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summarize", help="span tree + metric rollups")
    ps.add_argument("trace", help="JSONL trace file (from --trace)")
    pp = sub.add_parser("prom", help="metrics snapshot as Prometheus text")
    pp.add_argument("trace")
    args = p.parse_args(argv)
    if args.cmd == "summarize":
        return summarize(args.trace)
    return prom(args.trace)
