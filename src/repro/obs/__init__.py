"""repro.obs — unified tracing, metrics, and profiling for the HPClust stack.

One module-level recorder gates everything. Until ``configure()`` (or
``set_recorder()``) installs one, every entry point below is a near-free
no-op: ``span()`` returns the shared ``NULL_SPAN`` singleton and the metric
helpers return immediately — the hot paths in core/, kernels/, data/,
serving/ and runtime/ stay unperturbed (asserted in tests/test_obs.py).

Typical use (what the launch CLIs' ``--trace`` flag does)::

    from repro import obs

    obs.configure(jsonl="trace.jsonl")
    with obs.span("stream.window", window=0, rows=65536):
        ...
    obs.inc("stream.windows")
    obs.observe("serve.request_latency_s", 0.012)
    obs.event("resilience.preempted", step=7)
    obs.shutdown()               # metrics snapshot + close sinks

Read the trace back with ``python -m repro.obs summarize trace.jsonl``.
Device-side naming (``jax.named_scope``/``TraceAnnotation``/profiler
sessions/device memory) lives in ``repro.obs.jaxhooks``.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.obs.core import (  # noqa: F401
    NULL_SPAN,
    Clock,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    NullSpan,
    Recorder,
    Span,
    quantile,
)
from repro.obs.sinks import JsonlSink, ListSink, prometheus_text  # noqa: F401

_recorder: Optional[Recorder] = None


def get_recorder() -> Optional[Recorder]:
    return _recorder


def set_recorder(rec: Optional[Recorder]) -> Optional[Recorder]:
    """Install ``rec`` as the active recorder; returns the previous one so
    tests can restore it."""
    global _recorder
    prev = _recorder
    _recorder = rec
    return prev


def enabled() -> bool:
    """Gate for instrumentation whose *attributes* are expensive to compute —
    plain ``span()``/``inc()`` calls do not need it."""
    return _recorder is not None


def configure(
    *,
    jsonl: str | None = None,
    sinks: tuple = (),
    clock: Clock = time.monotonic,
    sync_kernels: bool = False,
) -> Recorder:
    """Build a ``Recorder`` (JSONL sink when ``jsonl`` is given, plus any
    extra ``sinks``), install it, and return it."""
    all_sinks = list(sinks)
    if jsonl is not None:
        all_sinks.append(JsonlSink(jsonl))
    rec = Recorder(tuple(all_sinks), clock=clock, sync_kernels=sync_kernels)
    set_recorder(rec)
    return rec


def span(name: str, **attrs):
    rec = _recorder
    if rec is None:
        return NULL_SPAN
    return rec.span(name, **attrs)


def event(name: str, **attrs) -> None:
    rec = _recorder
    if rec is not None:
        rec.event(name, **attrs)


def inc(name: str, n: float = 1.0) -> None:
    rec = _recorder
    if rec is not None:
        rec.inc(name, n)


def gauge(name: str, v: float) -> None:
    rec = _recorder
    if rec is not None:
        rec.gauge(name, v)


def observe(name: str, v: float) -> None:
    rec = _recorder
    if rec is not None:
        rec.observe(name, v)


def flush() -> None:
    rec = _recorder
    if rec is not None:
        rec.flush()


def shutdown() -> None:
    """Close the active recorder (final metrics snapshot + sink close) and
    uninstall it. Safe to call when nothing is configured."""
    global _recorder
    rec = _recorder
    _recorder = None
    if rec is not None:
        rec.close()


__all__ = [
    "NULL_SPAN",
    "Clock",
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "ListSink",
    "MetricRegistry",
    "NullSpan",
    "Recorder",
    "Span",
    "configure",
    "enabled",
    "event",
    "flush",
    "gauge",
    "get_recorder",
    "inc",
    "observe",
    "prometheus_text",
    "quantile",
    "set_recorder",
    "shutdown",
    "span",
]
