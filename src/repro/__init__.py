"""repro: HPClust (MSSC-ITD) as a production multi-pod JAX framework."""

__version__ = "1.0.0"
