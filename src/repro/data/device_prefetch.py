"""Double-buffered host-to-device window prefetch for the streaming tier.

``fit_stream`` / ``run_elastic_sharded`` consume an unbounded window stream;
without prefetch every window serializes host ingest -> sanitize -> H2D
transfer -> compute. This module overlaps the first three stages with the
fourth: while window *w* computes on the device, a background thread
sanitizes window *w+1* and lands it via ``jax.device_put`` (which is
asynchronous — the transfer itself overlaps compute; on the SPMD tier the
caller's ``place`` hook supplies the mesh's ``NamedSharding``). With a queue
depth of N the device always has up to N ready windows to chew through.

Bit-identity contract (tested in tests/test_throughput.py): the prefetched
stream yields EXACTLY what the synchronous path computes — same sanitize
call, same f32 conversion, same skip semantics for resumed (``start_at``)
and all-bad windows — so prefetch on/off cannot change results, only their
arrival time. Producer exceptions are re-raised in the consumer as the
ORIGINAL exception object (the chaos suites assert on exception types).

Observability: ``prefetch.depth`` (ready windows in the queue) and
``prefetch.overlap_s`` (host prepare seconds hidden behind device compute
for each window) gauges, when a ``repro.obs`` recorder is active.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, NamedTuple, Optional

import jax
import numpy as np

from repro import obs
from repro.resilience.sanitize import sanitize_window

_POLL_S = 0.2


class PrefetchedWindow(NamedTuple):
    """One stream window, sanitized and (unless skipped) device-resident."""

    index: int                    # position in the raw stream
    host: Optional[np.ndarray]    # sanitized f32 host copy; None => skip
    device: Any                   # placed device value (None when skipped)
    n_bad: int                    # non-finite rows repaired by sanitize
    flagged: bool = False         # flag_fn() sampled when this was pulled


class _Done:
    """Queue sentinel: the raw stream finished cleanly."""


class _Failure:
    """Queue sentinel carrying the producer thread's exception."""

    def __init__(self, exc: BaseException):
        self.exc = exc


def default_place(w: np.ndarray) -> jax.Array:
    """Single-device placement — identical to ``jnp.asarray(w, f32)`` for an
    f32 host array (the synchronous path's conversion)."""
    return jax.device_put(w)


def _prepare(
    wi: int,
    window: Any,
    sanitize: bool,
    place: Callable[[np.ndarray], Any],
    flagged: bool,
) -> PrefetchedWindow:
    """sanitize -> f32 -> device_put for one window (either thread)."""
    w = np.asarray(window)
    n_bad = 0
    if sanitize:
        with obs.span("sanitize.window"):
            w, n_bad = sanitize_window(w)
        if w is None:  # every row non-finite: the caller skips + counts it
            return PrefetchedWindow(wi, None, None, n_bad, flagged)
    w = np.asarray(w, np.float32)
    return PrefetchedWindow(wi, w, place(w), n_bad, flagged)


def device_stream(
    windows: Iterable[Any],
    *,
    depth: int,
    sanitize: bool = True,
    start_at: int = 0,
    place: Callable[[np.ndarray], Any] | None = None,
    flag_fn: Callable[[], bool] | None = None,
) -> Iterator[PrefetchedWindow]:
    """Yield ``PrefetchedWindow``s for ``windows[start_at:]``.

    ``depth <= 0`` is the synchronous fallback (no thread, no queue) — the
    opt-out path and the reference for the bit-identity contract. Windows
    below ``start_at`` (a checkpoint fast-forward) are consumed from the raw
    iterator without sanitizing, exactly like the pre-prefetch resume loop.

    ``place`` maps a sanitized f32 host array to its device form; the SPMD
    tier passes a broadcast + ``NamedSharding`` placement, everyone else
    gets ``default_place``. The host copy rides along in the yielded item so
    recovery paths can re-place the window after a mesh change.

    ``flag_fn`` is the preemption hook: it is sampled in PULL ORDER (right
    after each raw window is taken from ``windows``) and delivered as
    ``item.flagged``, so a consumer that stops on the first flagged item
    behaves identically whether the producer ran ahead or not. A True
    sample also ends production — a preempted stream must not keep pulling.
    """
    place = place or default_place
    if depth <= 0:
        for wi, window in enumerate(windows):
            if wi < start_at:
                continue
            flagged = bool(flag_fn()) if flag_fn is not None else False
            yield _prepare(wi, window, sanitize, place, flagged)
            if flagged:
                return
        return

    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def _put(item: Any) -> None:
        # Bounded put that gives up when the consumer has left (generator
        # closed): a daemon thread must never wedge on a full queue.
        while not stop.is_set():
            try:
                q.put(item, timeout=_POLL_S)
                return
            except queue.Full:
                continue

    def run() -> None:
        try:
            for wi, window in enumerate(windows):
                if stop.is_set():
                    return
                if wi < start_at:
                    continue
                flagged = bool(flag_fn()) if flag_fn is not None else False
                t0 = time.perf_counter()
                item = _prepare(wi, window, sanitize, place, flagged)
                _put((item, time.perf_counter() - t0))
                if flagged:
                    break
            _put(_Done())
        except BaseException as e:  # noqa: BLE001 — forwarded, never silent
            _put(_Failure(e))

    t = threading.Thread(
        target=run, name="repro-device-prefetch", daemon=True)
    t.start()
    try:
        while True:
            waited = 0.0
            while True:
                t0 = time.perf_counter()
                try:
                    got = q.get(timeout=_POLL_S)
                    waited += time.perf_counter() - t0
                    break
                except queue.Empty:
                    waited += time.perf_counter() - t0
                    if not t.is_alive() and q.empty():
                        raise RuntimeError(
                            "device prefetch thread died without reporting "
                            "an error"
                        ) from None
            if isinstance(got, _Done):
                return
            if isinstance(got, _Failure):
                raise got.exc  # the original exception, type preserved
            item, prep_s = got
            rec = obs.get_recorder()
            if rec is not None:
                rec.gauge("prefetch.depth", q.qsize())
                # Host prepare time hidden behind device compute: what the
                # consumer did NOT have to wait for.
                rec.gauge("prefetch.overlap_s", max(0.0, prep_s - waited))
            yield item
    finally:
        stop.set()
