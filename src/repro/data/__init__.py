from repro.data.device_prefetch import (
    PrefetchedWindow,
    default_place,
    device_stream,
)
from repro.data.pipeline import (
    PipelineError,
    blob_stream,
    device_windows,
    gaussian_blobs,
    prefetch_iter,
    token_batches,
)

__all__ = [
    "PipelineError",
    "PrefetchedWindow",
    "blob_stream",
    "default_place",
    "device_stream",
    "device_windows",
    "gaussian_blobs",
    "prefetch_iter",
    "token_batches",
]
