from repro.data.pipeline import gaussian_blobs, blob_stream, token_batches

__all__ = ["gaussian_blobs", "blob_stream", "token_batches"]
