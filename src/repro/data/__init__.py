from repro.data.pipeline import (
    PipelineError,
    blob_stream,
    gaussian_blobs,
    prefetch_iter,
    token_batches,
)

__all__ = [
    "PipelineError",
    "blob_stream",
    "gaussian_blobs",
    "prefetch_iter",
    "token_batches",
]
