"""Data pipeline: synthetic clustering streams + LM token batches.

Clustering side (the paper's workload):
  * ``gaussian_blobs``   — the scaling-experiment generator (SS6.8): 10-dim,
    10 blobs uniform in (-40,40)^n, per-blob sigma ~ U(0,10), plus 500
    uniform noise points in (-50,50)^n.
  * ``blob_stream``      — an infinite window generator over the same
    distribution: the MSSC-ITD "infinitely tall" data source.

LM side:
  * ``token_batches``    — synthetic Zipf-distributed token streams with a
    background prefetch thread (double buffering), matching the batch
    structure of ``launch/steps.py``.

Hardened I/O edge (docs/resilience.md): ``prefetch_iter`` runs any generator
factory on a background thread behind a bounded queue. Producer exceptions
propagate to the consumer through a sentinel (never a silent hang), ``get``
uses bounded timeouts so a dead thread can't block forever, and the producer
is restarted with capped, jittered exponential backoff before
``PipelineError`` gives up.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import numpy as np

from repro import obs
from repro.resilience import retry


class PipelineError(RuntimeError):
    """The prefetch producer died more times than the restart budget allows
    (or stopped making progress past ``max_idle_s``)."""


class _ProducerFailure:
    """Queue sentinel carrying the producer thread's exception."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class _ProducerDone:
    """Queue sentinel: the generator finished cleanly (finite source)."""


def prefetch_iter(
    make_gen: Callable[[], Iterator],
    *,
    size: int = 2,
    max_restarts: int = 3,
    poll_s: float = 1.0,
    max_idle_s: Optional[float] = None,
    policy: Optional[retry.RetryPolicy] = None,
    sleep: Callable[[float], None] = time.sleep,
    seed: int = 0,
) -> Iterator:
    """Consume ``make_gen()`` through a bounded background-prefetch queue.

    The producer thread catches everything it raises and forwards it as a
    sentinel; the consumer restarts the producer (a fresh ``make_gen()``
    call) with backoff up to ``max_restarts`` times, then raises
    ``PipelineError`` from the last producer error. ``poll_s`` bounds every
    ``q.get`` so a producer that dies without reporting (killed thread) is
    detected rather than hung on; ``max_idle_s`` optionally bounds how long
    a live-but-stuck producer may go without yielding.
    """
    policy = policy or retry.RetryPolicy(
        max_attempts=max_restarts + 1, base_delay=0.02, max_delay=0.5
    )
    q: queue.Queue = queue.Queue(maxsize=size)

    def run(gen: Iterator) -> None:
        try:
            for item in gen:
                q.put(item)
            q.put(_ProducerDone())
        except BaseException as e:  # noqa: BLE001 — propagate, never die silent
            q.put(_ProducerFailure(e))

    def start() -> threading.Thread:
        t = threading.Thread(target=run, args=(make_gen(),), daemon=True)
        t.start()
        return t

    t = start()
    restarts = 0
    delays = retry.backoff_delays(policy, seed=seed)
    idle = 0.0
    while True:
        # Per-iteration recorder lookup: tracing can be enabled mid-stream
        # and a disabled loop must not hold a stale recorder alive.
        rec = obs.get_recorder()
        if rec is not None:
            rec.gauge("pipeline.queue_depth", q.qsize())
        try:
            with (rec.span("prefetch.wait") if rec is not None
                  else obs.NULL_SPAN):
                item = q.get(timeout=poll_s)
        except queue.Empty:
            if t.is_alive():
                idle += poll_s
                if max_idle_s is not None and idle >= max_idle_s:
                    raise PipelineError(
                        f"prefetch producer made no progress for {idle:.1f}s"
                    )
                continue  # slow producer: keep waiting, bounded by max_idle_s
            item = _ProducerFailure(
                RuntimeError("prefetch thread died without reporting an error")
            )
        idle = 0.0
        if isinstance(item, _ProducerDone):
            return
        if isinstance(item, _ProducerFailure):
            restarts += 1
            if rec is not None:
                rec.inc("pipeline.restarts")
                rec.event(
                    "pipeline.producer_failure",
                    error=type(item.exc).__name__,
                    restarts=restarts,
                    budget=max_restarts,
                )
            if restarts > max_restarts:
                raise PipelineError(
                    f"prefetch producer failed {restarts} time(s); "
                    f"restart budget ({max_restarts}) exhausted"
                ) from item.exc
            sleep(next(delays))
            t = start()
            continue
        if rec is not None:
            rec.inc("pipeline.items")
        yield item


def device_windows(
    make_gen: Callable[[], Iterator],
    *,
    depth: int = 2,
    sanitize: bool = True,
    start_at: int = 0,
    place=None,
    **prefetch_kwargs,
) -> Iterator:
    """Full 3-stage streaming pipeline (docs/performance.md):

      host ingest (supervised ``prefetch_iter`` thread)
        -> sanitize + H2D (``device_stream`` thread)
          -> compute (the caller).

    Yields ``repro.data.device_prefetch.PrefetchedWindow`` items. Both
    threaded stages degrade independently: ``depth<=0`` makes the H2D stage
    synchronous, ``prefetch_kwargs['size']=0`` is rejected by ``queue`` so
    ingest supervision always runs.
    """
    from repro.data import device_prefetch

    src = prefetch_iter(make_gen, **prefetch_kwargs)
    return device_prefetch.device_stream(
        src, depth=depth, sanitize=sanitize, start_at=start_at, place=place)


def gaussian_blobs(
    m: int,
    *,
    n: int = 10,
    k: int = 10,
    noise_points: int = 500,
    box: float = 40.0,
    sigma_max: float = 10.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (X (m+noise, n) f32, true_centers (k, n))."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-box, box, size=(k, n))
    sigmas = rng.uniform(0.0, sigma_max, size=(k,))
    counts = np.full((k,), m // k)
    counts[: m % k] += 1
    parts = [
        centers[j] + sigmas[j] * rng.standard_normal((counts[j], n))
        for j in range(k)
    ]
    if noise_points:
        parts.append(rng.uniform(-box - 10, box + 10, size=(noise_points, n)))
    x = np.concatenate(parts).astype(np.float32)
    rng.shuffle(x)
    return x, centers.astype(np.float32)


def blob_stream(
    window: int,
    *,
    n: int = 10,
    k: int = 10,
    noise_frac: float = 0.05,
    seed: int = 0,
) -> Iterator[np.ndarray]:
    """Infinite stream of (window, n) arrays from a FIXED blob distribution —
    the MSSC-ITD source: same mixture, unbounded rows."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-40, 40, size=(k, n))
    sigmas = rng.uniform(0.0, 10.0, size=(k,))
    while True:
        comp = rng.integers(0, k, size=window)
        x = centers[comp] + sigmas[comp, None] * rng.standard_normal((window, n))
        n_noise = int(window * noise_frac)
        if n_noise:
            idx = rng.choice(window, n_noise, replace=False)
            x[idx] = rng.uniform(-50, 50, size=(n_noise, n))
        yield x.astype(np.float32)


def token_batches(
    vocab: int,
    batch: int,
    seq: int,
    *,
    seed: int = 0,
    zipf_a: float = 1.2,
    prefetch: int = 2,
    max_restarts: int = 3,
) -> Iterator[dict]:
    """Infinite {'tokens': (B, S) int32} batches, prefetched on a thread.

    The prefetch edge is supervised (``prefetch_iter``): a dying producer is
    restarted from the same seed up to ``max_restarts`` times — the source is
    synthetic and i.i.d., so a restart just re-draws batches.
    """

    def gen() -> Iterator[dict]:
        rng = np.random.default_rng(seed)
        while True:
            t = rng.zipf(zipf_a, size=(batch, seq)).astype(np.int64)
            t = np.minimum(t - 1, vocab - 1).astype(np.int32)
            yield {"tokens": t}

    yield from prefetch_iter(gen, size=prefetch, max_restarts=max_restarts)
