"""Data pipeline: synthetic clustering streams + LM token batches.

Clustering side (the paper's workload):
  * ``gaussian_blobs``   — the scaling-experiment generator (SS6.8): 10-dim,
    10 blobs uniform in (-40,40)^n, per-blob sigma ~ U(0,10), plus 500
    uniform noise points in (-50,50)^n.
  * ``blob_stream``      — an infinite window generator over the same
    distribution: the MSSC-ITD "infinitely tall" data source.

LM side:
  * ``token_batches``    — synthetic Zipf-distributed token streams with a
    background prefetch thread (double buffering), matching the batch
    structure of ``launch/steps.py``.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


def gaussian_blobs(
    m: int,
    *,
    n: int = 10,
    k: int = 10,
    noise_points: int = 500,
    box: float = 40.0,
    sigma_max: float = 10.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (X (m+noise, n) f32, true_centers (k, n))."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-box, box, size=(k, n))
    sigmas = rng.uniform(0.0, sigma_max, size=(k,))
    counts = np.full((k,), m // k)
    counts[: m % k] += 1
    parts = [
        centers[j] + sigmas[j] * rng.standard_normal((counts[j], n))
        for j in range(k)
    ]
    if noise_points:
        parts.append(rng.uniform(-box - 10, box + 10, size=(noise_points, n)))
    x = np.concatenate(parts).astype(np.float32)
    rng.shuffle(x)
    return x, centers.astype(np.float32)


def blob_stream(
    window: int,
    *,
    n: int = 10,
    k: int = 10,
    noise_frac: float = 0.05,
    seed: int = 0,
) -> Iterator[np.ndarray]:
    """Infinite stream of (window, n) arrays from a FIXED blob distribution —
    the MSSC-ITD source: same mixture, unbounded rows."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-40, 40, size=(k, n))
    sigmas = rng.uniform(0.0, 10.0, size=(k,))
    while True:
        comp = rng.integers(0, k, size=window)
        x = centers[comp] + sigmas[comp, None] * rng.standard_normal((window, n))
        n_noise = int(window * noise_frac)
        if n_noise:
            idx = rng.choice(window, n_noise, replace=False)
            x[idx] = rng.uniform(-50, 50, size=(n_noise, n))
        yield x.astype(np.float32)


def token_batches(
    vocab: int,
    batch: int,
    seq: int,
    *,
    seed: int = 0,
    zipf_a: float = 1.2,
    prefetch: int = 2,
) -> Iterator[dict]:
    """Infinite {'tokens': (B, S) int32} batches, prefetched on a thread."""

    def gen(q: queue.Queue):
        rng = np.random.default_rng(seed)
        while True:
            t = rng.zipf(zipf_a, size=(batch, seq)).astype(np.int64)
            t = np.minimum(t - 1, vocab - 1).astype(np.int32)
            q.put({"tokens": t})

    q: queue.Queue = queue.Queue(maxsize=prefetch)
    threading.Thread(target=gen, args=(q,), daemon=True).start()
    while True:
        yield q.get()
