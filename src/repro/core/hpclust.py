"""Public HPClust API: fit arrays, fit infinite streams, assign big data.

``HPClust`` is the user-facing estimator; ``fit_stream`` implements the
MSSC-ITD semantics the paper introduces: the algorithm never assumes X fits
anywhere — it consumes a window iterator (the "infinitely tall" stream),
keeps a device-resident reservoir window, and carries worker incumbents
across windows. More rounds / more windows can only improve the incumbent
(keep-the-best), which is the paper's central monotonicity property.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import strategies
from repro.core.strategies import HPClustConfig, WorkerState
from repro.kernels import ops

Array = jax.Array


class HPClustResult(NamedTuple):
    centroids: np.ndarray       # (k, d)
    objective: float            # best incumbent sample objective
    history: np.ndarray         # (rounds_total, W) incumbent objective per round
    state: WorkerState          # final worker states (for warm restarts)


@dataclasses.dataclass
class HPClust:
    """Estimator wrapper around the compiled strategy engine."""

    config: HPClustConfig
    seed: int = 0

    def fit(self, x: np.ndarray | Array) -> HPClustResult:
        """Cluster a (m, d) window (single-shot MSSC)."""
        key = jax.random.PRNGKey(self.seed)
        data = jnp.asarray(x, jnp.float32)
        state, metrics = _jit_run_hpclust(key, data, cfg=self.config)
        c, obj = strategies.best_of(state)
        return HPClustResult(
            centroids=np.asarray(c),
            objective=float(obj),
            history=np.asarray(metrics.best_obj),
            state=state,
        )

    def fit_stream(
        self,
        windows: Iterable[np.ndarray],
        *,
        rounds_per_window: int | None = None,
    ) -> HPClustResult:
        """MSSC-ITD: consume successive stream windows, carrying incumbents.

        Each window is a (m_w, d) array (m_w may vary; it is the reservoir
        the host has streamed in). Worker incumbents, objectives and PRNG
        state persist across windows — the algorithm behaves as if it sampled
        one infinite dataset.
        """
        cfg = self.config
        rpw = rounds_per_window or cfg.rounds
        run_cfg = dataclasses.replace(cfg, rounds=rpw)
        key = jax.random.PRNGKey(self.seed)
        state: WorkerState | None = None
        hist = []
        for wi, window in enumerate(windows):
            data = jnp.asarray(window, jnp.float32)
            if state is None:
                key, k0 = jax.random.split(key)
                state = strategies.init_state(k0, run_cfg, data.shape[1])
            state, metrics = _jit_run_from_state(state, data, cfg=run_cfg)
            del wi
            hist.append(np.asarray(metrics.best_obj))
        if state is None:
            raise ValueError("empty stream")
        c, obj = strategies.best_of(state)
        return HPClustResult(
            centroids=np.asarray(c),
            objective=float(obj),
            history=np.concatenate(hist, axis=0),
            state=state,
        )

    def assign(
        self, x: np.ndarray | Array, centroids: np.ndarray | Array,
        *, batch: int = 1 << 16,
    ) -> np.ndarray:
        """Final full-dataset assignment (paper SS3 last step), batched."""
        # ops.assign_clusters is already jitted at module level; calling it
        # directly shares one compile cache across every estimator instance.
        c = jnp.asarray(centroids, jnp.float32)
        out = []
        x = np.asarray(x, np.float32)
        for i in range(0, len(x), batch):
            idx, _ = ops.assign_clusters(
                jnp.asarray(x[i : i + batch]), c, impl=self.config.impl
            )
            out.append(np.asarray(idx))
        return np.concatenate(out) if out else np.zeros((0,), np.int32)

    def objective(self, x, centroids, *, batch: int = 1 << 16) -> float:
        """f(C, X) over a full dataset, streamed in batches."""
        c = jnp.asarray(centroids, jnp.float32)
        x = np.asarray(x, np.float32)
        total = 0.0
        for i in range(0, len(x), batch):
            total += float(
                ops.mssc_objective(
                    jnp.asarray(x[i : i + batch]), c, impl=self.config.impl
                )
            )
        return total


def _run_from_state(state: WorkerState, data: Array, *, cfg: HPClustConfig):
    """run_rounds, jit-friendly keyword-static wrapper."""
    return strategies.run_rounds(state, data, cfg)


# Jitted once at import: a fresh jax.jit wrapper per fit()/fit_stream() call
# would key the compile cache on the wrapper identity and re-trace for every
# estimator instance (analysis check JH003).
_jit_run_hpclust = jax.jit(strategies.run_hpclust, static_argnames=("cfg",))
_jit_run_from_state = jax.jit(_run_from_state, static_argnames=("cfg",))


def stream_from_generator(
    gen: Iterator[np.ndarray], max_windows: int
) -> Iterable[np.ndarray]:
    """Utility: cap an infinite generator at max_windows windows."""
    for i, w in enumerate(gen):
        if i >= max_windows:
            return
        yield w
