"""Public HPClust API: fit arrays, fit infinite streams, assign big data.

``HPClust`` is the user-facing estimator; ``fit_stream`` implements the
MSSC-ITD semantics the paper introduces: the algorithm never assumes X fits
anywhere — it consumes a window iterator (the "infinitely tall" stream),
keeps a device-resident reservoir window, and carries worker incumbents
across windows. More rounds / more windows can only improve the incumbent
(keep-the-best), which is the paper's central monotonicity property.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags, obs
from repro.core import strategies
from repro.core.strategies import HPClustConfig, RoundMetrics, WorkerState
from repro.data import device_prefetch
from repro.kernels import ops
from repro.resilience.preemption import PreemptionGuard
from repro.resilience.stream_ckpt import StreamCheckpointer

Array = jax.Array


def _emit_round_metrics(metrics: RoundMetrics, *, window: int | None = None) -> None:
    """Publish per-round competition telemetry (objective descent, accepted
    rounds, quarantines) as ``hpclust.round`` trace events. No-op (and no
    device->host transfer) when tracing is disabled."""
    rec = obs.get_recorder()
    if rec is None:
        return
    best = np.asarray(metrics.best_obj)        # (rounds, W)
    accepted = np.asarray(metrics.accepted)
    quarantined = np.asarray(metrics.quarantined)
    w = best.shape[1] if best.ndim == 2 else 1
    for r in range(best.shape[0]):
        rec.event(
            "hpclust.round",
            round=r,
            window=window,
            best_obj=float(best[r].min()),
            accepted=f"{int(accepted[r].sum())}/{w}",
            quarantined=int(quarantined[r].sum()),
        )
    rec.inc("hpclust.rounds", int(best.shape[0]))
    n_quar = int(quarantined.sum())
    if n_quar:
        rec.inc("resilience.quarantined_workers", n_quar)
        rec.event("resilience.quarantine", window=window, workers=n_quar)


class StreamStats(NamedTuple):
    """Supervision counters for one ``fit_stream`` run."""

    windows: int                # windows consumed (incl. skipped/resumed)
    sanitized_rows: int         # non-finite rows masked/dropped, cumulative
    preempted: bool             # stopped early at a preemption signal
    resumed_at: int | None      # window index restored from checkpoint


class HPClustResult(NamedTuple):
    centroids: np.ndarray       # (k, d)
    objective: float            # best incumbent sample objective
    history: np.ndarray         # (rounds_total, W) incumbent objective per round
    state: WorkerState          # final worker states (for warm restarts)
    stats: StreamStats | None = None  # stream supervision counters (fit_stream)


@dataclasses.dataclass
class HPClust:
    """Estimator wrapper around the compiled strategy engine.

    ``prefetch`` controls the device-prefetch depth for ``fit_stream``:
    ``None``/``True`` -> the ``REPRO_PREFETCH`` default (2), ``False``/``0``
    -> fully synchronous, an int -> that queue depth. Results are
    bit-identical either way (docs/performance.md).
    """

    config: HPClustConfig
    seed: int = 0
    prefetch: int | bool | None = None

    def fit(self, x: np.ndarray | Array) -> HPClustResult:
        """Cluster a (m, d) window (single-shot MSSC)."""
        key = jax.random.PRNGKey(self.seed)
        data = jnp.asarray(x, jnp.float32)
        with obs.span("hpclust.fit", rows=int(data.shape[0]),
                      strategy=self.config.strategy, k=self.config.k,
                      workers=self.config.workers):
            state, metrics = _jit_run_hpclust(key, data, cfg=self.config)
            _emit_round_metrics(metrics)
        c, obj = strategies.best_of(state)
        return HPClustResult(
            centroids=np.asarray(c),
            objective=float(obj),
            history=np.asarray(metrics.best_obj),
            state=state,
        )

    def fit_stream(
        self,
        windows: Iterable[np.ndarray],
        *,
        rounds_per_window: int | None = None,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 1,
        resume: bool = False,
        sanitize: bool = True,
        preemption_guard: PreemptionGuard | None = None,
    ) -> HPClustResult:
        """MSSC-ITD: consume successive stream windows, carrying incumbents.

        Each window is a (m_w, d) array (m_w may vary; it is the reservoir
        the host has streamed in). Worker incumbents, objectives and PRNG
        state persist across windows — the algorithm behaves as if it sampled
        one infinite dataset.

        Supervision (all optional, see docs/resilience.md):
          * ``checkpoint_dir`` — save a ``WorkerState`` checkpoint every
            ``checkpoint_every`` windows (atomic; window index = step). A
            crash mid-stream also checkpoints the last good state before the
            exception propagates.
          * ``resume`` — restore the latest checkpoint and fast-forward the
            stream past the windows it already covers. With a deterministic
            source the resumed run replays the uninterrupted one exactly;
            by keep-the-best monotonicity it can only match-or-improve.
          * ``sanitize`` — mask non-finite rows host-side (counted in
            ``result.stats.sanitized_rows``); an all-bad window is skipped.
          * preemption — SIGTERM (or ``preemption_guard.trigger()``) stops at
            the next window boundary after checkpointing; the result carries
            ``stats.preempted=True``.
        """
        cfg = self.config
        rpw = rounds_per_window or cfg.rounds
        run_cfg = dataclasses.replace(cfg, rounds=rpw)
        key = jax.random.PRNGKey(self.seed)
        state: WorkerState | None = None
        hist: list[np.ndarray] = []
        sanitized_rows = 0
        windows_done = 0
        resumed_at: int | None = None
        preempted = False

        ckpt = None
        if checkpoint_dir is not None:
            ckpt = StreamCheckpointer(checkpoint_dir)
        if resume:
            if ckpt is None:
                raise ValueError("resume=True requires checkpoint_dir")
            restored = ckpt.restore(run_cfg)
            if restored is not None:
                windows_done = restored.windows_done
                state = restored.state
                sanitized_rows = restored.sanitized_rows
                resumed_at = windows_done
                if restored.history.size:
                    hist.append(restored.history)
                obs.event("resilience.resumed", window=windows_done)

        def _history() -> np.ndarray:
            if not hist:
                return np.zeros((0, run_cfg.workers), np.float32)
            return np.concatenate(hist, axis=0)

        own_guard = preemption_guard is None
        guard = PreemptionGuard() if own_guard else preemption_guard
        if own_guard:
            guard.install()
        donate = flags.donate_enabled()
        run_fn = _jit_run_from_state_donated if donate else _jit_run_from_state
        # Sanitize + H2D run on a background thread while the previous window
        # computes (depth 0 = the synchronous path, bit-identical).
        stream = device_prefetch.device_stream(
            windows,
            depth=flags.prefetch_depth(self.prefetch),
            sanitize=sanitize,
            start_at=windows_done,
            # Preemption is sampled in PULL order and delivered per item, so
            # the stop window is the same whether the producer ran ahead
            # (prefetch on) or not (see device_prefetch.device_stream).
            flag_fn=lambda: guard.preempted,
        )
        try:
            for item in stream:
                wi = item.index
                if item.flagged:
                    preempted = True
                    break
                with obs.span("stream.window", window=wi) as w_span:
                    sanitized_rows += item.n_bad
                    if item.n_bad:
                        obs.inc("stream.sanitized_rows", item.n_bad)
                    if item.host is None:  # every row non-finite: skip
                        windows_done = wi + 1
                        obs.event("stream.window_skipped", window=wi)
                        continue
                    data = item.device
                    w_span.set(rows=int(data.shape[0]))
                    if state is None:
                        key, k0 = jax.random.split(key)
                        state = strategies.init_state(
                            k0, run_cfg, data.shape[1])
                    # Donation deletes the input state's buffers even when
                    # the step fails — keep a host snapshot so the crash
                    # checkpoint below can never read a donated buffer.
                    snapshot = None
                    if donate and ckpt is not None:
                        snapshot = jax.device_get(state)
                    with obs.span("hpclust.rounds", rounds=run_cfg.rounds):
                        try:
                            state, metrics = run_fn(state, data, cfg=run_cfg)
                        except BaseException:
                            if snapshot is not None:
                                state = snapshot
                            raise
                        _emit_round_metrics(metrics, window=wi)
                    hist.append(np.asarray(metrics.best_obj))
                    windows_done = wi + 1
                    obs.inc("stream.windows")
                    obs.inc("stream.rows", int(data.shape[0]))
                    if ckpt is not None \
                            and windows_done % checkpoint_every == 0:
                        with obs.span("ckpt.save", window=windows_done):
                            ckpt.save(windows_done, state, _history(),
                                      sanitized_rows)
        except BaseException:
            # A dying stream (or step) must not lose the incumbents: persist
            # the last good state, then let the original failure propagate.
            if ckpt is not None and state is not None and windows_done > 0:
                try:
                    ckpt.save(windows_done, state, _history(), sanitized_rows)
                except Exception:
                    pass  # never mask the original failure with a save error
            raise
        finally:
            stream.close()  # deterministic prefetch-thread shutdown
            if own_guard:
                guard.restore()

        # A signal that landed during the final window's compute (stream
        # already exhausted) still counts as a preemption.
        preempted = preempted or guard.preempted
        if preempted:
            obs.event("resilience.preempted", window=windows_done)
        if preempted and ckpt is not None and state is not None \
                and windows_done > 0:
            ckpt.save(windows_done, state, _history(), sanitized_rows)
        if state is None:
            raise ValueError("empty stream")
        c, obj = strategies.best_of(state)
        return HPClustResult(
            centroids=np.asarray(c),
            objective=float(obj),
            history=_history(),
            state=state,
            stats=StreamStats(
                windows=windows_done,
                sanitized_rows=sanitized_rows,
                preempted=preempted,
                resumed_at=resumed_at,
            ),
        )

    def assign(
        self, x: np.ndarray | Array, centroids: np.ndarray | Array,
        *, batch: int = 1 << 16,
    ) -> np.ndarray:
        """Final full-dataset assignment (paper SS3 last step), batched."""
        # ops.assign_clusters dispatches through one module-level jit, so
        # every estimator instance shares a single compile cache.
        c = jnp.asarray(centroids, jnp.float32)
        out = []
        x = np.asarray(x, np.float32)
        with obs.span("hpclust.assign", rows=len(x), batch=batch):
            for i in range(0, len(x), batch):
                idx, _ = ops.assign_clusters(
                    jnp.asarray(x[i : i + batch]), c, impl=self.config.impl
                )
                out.append(np.asarray(idx))
        return np.concatenate(out) if out else np.zeros((0,), np.int32)

    def objective(self, x, centroids, *, batch: int = 1 << 16) -> float:
        """f(C, X) over a full dataset, streamed in batches.

        The ragged tail batch is padded back up to the fixed ``batch`` shape
        so ONE compiled program serves the whole pass (a (m % batch, d) tail
        used to retrace). Pad rows are copies of centroid 0 — distance 0 to
        their nearest centroid — and any numerical residue is measured with
        a fixed (1, d) probe and subtracted, so the value is unchanged.
        """
        c = jnp.asarray(centroids, jnp.float32)
        c0 = np.asarray(c)[0]
        x = np.asarray(x, np.float32)
        impl = self.config.impl
        total = 0.0
        with obs.span("hpclust.objective", rows=len(x), batch=batch):
            for i in range(0, len(x), batch):
                sl = x[i : i + batch]
                n_pad = batch - len(sl) if len(x) > batch else 0
                if n_pad:
                    sl = np.concatenate(
                        [sl, np.broadcast_to(c0, (n_pad, c0.shape[0]))])
                total += float(
                    ops.mssc_objective(jnp.asarray(sl), c, impl=impl))
                if n_pad:
                    total -= n_pad * float(
                        ops.mssc_objective(jnp.asarray(c0[None]), c,
                                           impl=impl))
        return total


def _run_from_state(state: WorkerState, data: Array, *, cfg: HPClustConfig):
    """run_rounds, jit-friendly keyword-static wrapper."""
    return strategies.run_rounds(state, data, cfg)


# Jitted once at import: a fresh jax.jit wrapper per fit()/fit_stream() call
# would key the compile cache on the wrapper identity and re-trace for every
# estimator instance (analysis check JH003). The donated variant reuses the
# input WorkerState's buffers for the output carry (REPRO_DONATE, default
# on); it is a SEPARATE jit object so flipping the flag mid-process can
# never alias a stale compile-cache entry.
_jit_run_hpclust = jax.jit(strategies.run_hpclust, static_argnames=("cfg",))
_jit_run_from_state = jax.jit(_run_from_state, static_argnames=("cfg",))
_jit_run_from_state_donated = jax.jit(
    _run_from_state, static_argnames=("cfg",), donate_argnums=(0,))


def stream_from_generator(
    gen: Iterator[np.ndarray], max_windows: int
) -> Iterable[np.ndarray]:
    """Utility: cap an infinite generator at max_windows windows."""
    for i, w in enumerate(gen):
        if i >= max_windows:
            return
        yield w
