"""HPClust parallel strategies (paper SS4, Algorithms 3-5) as one XLA program.

The paper runs OS threads that mutate shared incumbents under locks. Here the
entire multi-round, multi-worker search compiles to a single ``lax.scan``:

  * workers are a leading axis handled by ``vmap`` (this module — the
    reference/host implementation) or by the ``data`` mesh axis via
    ``shard_map`` (``repro.core.sharded`` — the pod implementation);
  * "keep the best" is a pure ``jnp.where`` — race-free by construction;
  * cooperative sharing is an argmin-select over the worker axis (a masked
    ``psum`` in the sharded twin);
  * the hybrid T1/T2 wall-clock split becomes a round-count split
    (``t1_rounds``), flipping a per-round coordination flag.

Strategies:
  inner        — ONE worker (all parallelism inside the distance evals;
                 on the mesh this is the `model` axis — here it degrades to
                 vmapped/W=1 execution).
  competitive  — W workers, never communicate, argmin at the end (Alg. 3).
  cooperative  — every round each worker restarts from the global best (Alg. 4).
  hybrid       — competitive for t1_rounds, cooperative after (Alg. 5).
  hybrid2      — beyond-paper: hierarchical hybrid for multi-pod meshes;
                 on the vmap path it behaves like hybrid with group-local
                 cooperation (groups = pods) + rare cross-group sync.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as km
from repro.core import kmeanspp
from repro.obs import jaxhooks

Array = jax.Array

STRATEGIES = ("inner", "sequential", "competitive", "cooperative", "hybrid", "hybrid2")


@dataclasses.dataclass(frozen=True)
class HPClustConfig:
    """Static configuration of one HPClust run (paper SS6.5 defaults)."""

    k: int                      # number of clusters
    sample_size: int            # s
    workers: int = 8            # W (paper: 8 CPUs)
    rounds: int = 16            # stop condition: max processed samples / worker
    strategy: str = "hybrid"
    t1_rounds: int | None = None  # hybrid switch point; default rounds // 2
    kmeans_iters: int = 300     # paper SS6.5
    kmeans_tol: float = 1e-4    # paper SS6.5
    n_candidates: int = 3       # K-means++ greedy candidates (paper SS6.5)
    groups: int = 1             # hybrid2: number of pods / worker groups
    sync_every: int = 4         # hybrid2: cross-group cooperation period
    impl: str | None = None     # kernel impl: auto/pallas/interpret/ref
    fixed_schedule: bool = False  # use kmeans_fixed (static SPMD trip count)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy {self.strategy!r} not in {STRATEGIES}")
        if self.workers < 1 or self.k < 1 or self.sample_size < 1:
            raise ValueError("workers, k and sample_size must be positive")
        if self.strategy == "hybrid2" and self.workers % self.groups:
            raise ValueError("hybrid2 needs workers divisible by groups")

    @property
    def effective_t1(self) -> int:
        return self.rounds // 2 if self.t1_rounds is None else self.t1_rounds


class WorkerState(NamedTuple):
    centroids: Array   # (W, k, d) f32 incumbent C_w
    best_obj: Array    # (W,) f32 incumbent sample objective \hat f_w
    degenerate: Array  # (W, k) bool — empty clusters of the incumbent
    key: Array         # (W, 2) uint32 per-worker PRNG


class RoundMetrics(NamedTuple):
    best_obj: Array      # (W,) incumbent objective after the round
    accepted: Array      # (W,) bool — did the round improve the incumbent
    kmeans_iters: Array  # (W,) int32
    quarantined: Array   # (W,) bool — poisoned incumbent re-seeded this round


def _mask_nonfinite(obj: Array) -> Array:
    """Objectives safe for argmin/select: NaN (poisoned arithmetic) and -inf
    (corrupt window) map to +inf so they can never win a selection; +inf is
    the legitimate "no incumbent yet" sentinel and maps to itself."""
    return jnp.where(jnp.isfinite(obj), obj, jnp.inf)


def quarantine_nonfinite(state: WorkerState) -> tuple[WorkerState, Array]:
    """Re-seed poisoned workers from the healthiest survivor.

    A worker is poisoned when its incumbent objective is NaN/-inf or any
    incumbent centroid is non-finite. It is quarantined by copying the
    healthiest (finite-argmin) survivor's centroids and degenerate mask and
    resetting its objective to +inf — so its next round warm-starts from the
    survivor (degenerate rows re-drawn by ``kmeanspp.reseed_degenerate`` in
    ``_worker_round``) and any finite result is accepted. If *every* worker
    is poisoned, all reset to the virgin all-degenerate state and the search
    re-seeds from scratch, exactly like round 0.
    """
    finite_c = jnp.all(jnp.isfinite(state.centroids), axis=(1, 2))
    bad = jnp.isnan(state.best_obj) | (state.best_obj == -jnp.inf) | ~finite_c
    donor = jnp.argmin(jnp.where(bad, jnp.inf, state.best_obj))
    donor_bad = bad[donor]  # true only when every worker is poisoned
    donor_c = jnp.where(donor_bad, 0.0, state.centroids[donor])
    donor_d = jnp.where(donor_bad, True, state.degenerate[donor])
    new_c = jnp.where(bad[:, None, None], donor_c[None], state.centroids)
    new_o = jnp.where(bad, jnp.inf, state.best_obj)
    new_d = jnp.where(bad[:, None], donor_d[None], state.degenerate)
    return WorkerState(new_c, new_o, new_d, state.key), bad


def init_state(key: Array, cfg: HPClustConfig, d: int) -> WorkerState:
    """All centroids degenerate, objectives +inf (Algorithms 3-5, lines 1-4)."""
    w = cfg.workers
    return WorkerState(
        centroids=jnp.zeros((w, cfg.k, d), jnp.float32),
        best_obj=jnp.full((w,), jnp.inf, jnp.float32),
        degenerate=jnp.ones((w, cfg.k), jnp.bool_),
        key=jax.random.split(key, w),
    )


def _worker_round(
    state_c: Array,
    state_obj: Array,
    state_deg: Array,
    key: Array,
    base_c: Array,
    base_deg: Array,
    sample: Array,
    cfg: HPClustConfig,
):
    """One HPClust round for one worker (Algorithm 3 lines 7-18)."""
    key, k_seed = jax.random.split(key)
    seeded = kmeanspp.reseed_degenerate(
        k_seed, sample, base_c, base_deg, n_candidates=cfg.n_candidates
    )
    if cfg.fixed_schedule:
        res = km.kmeans_fixed(
            sample, seeded, iters=min(cfg.kmeans_iters, 64), tol=cfg.kmeans_tol,
            impl=cfg.impl,
        )
    else:
        res = km.kmeans(
            sample, seeded, max_iters=cfg.kmeans_iters, tol=cfg.kmeans_tol,
            impl=cfg.impl,
        )
    # A non-finite candidate objective (corrupt sample, degenerate math) can
    # never displace the incumbent — -inf would otherwise "win" the compare.
    accept = (res.objective < state_obj) & jnp.isfinite(res.objective)
    new_c = jnp.where(accept, res.centroids, state_c)
    new_obj = jnp.where(accept, res.objective, state_obj)
    new_deg = jnp.where(accept, res.counts == 0, state_deg)
    return new_c, new_obj, new_deg, key, accept, res.iterations


def _select_base(state: WorkerState, coop: Array, cfg: HPClustConfig):
    """Per-round warm-start selection: own incumbent vs (group) best."""
    w = cfg.workers
    if cfg.strategy in ("inner", "sequential", "competitive"):
        return state.centroids, state.degenerate
    if cfg.strategy == "hybrid2":
        g = cfg.groups
        per = w // g
        obj_g = _mask_nonfinite(state.best_obj).reshape(g, per)
        best_in_group = jnp.argmin(obj_g, axis=1)  # (g,)
        flat_best = best_in_group + jnp.arange(g) * per  # index into W
        base_c_g = state.centroids[flat_best]  # (g, k, d)
        base_d_g = state.degenerate[flat_best]
        base_c = jnp.repeat(base_c_g, per, axis=0)
        base_d = jnp.repeat(base_d_g, per, axis=0)
    else:
        best = jnp.argmin(_mask_nonfinite(state.best_obj))
        base_c = jnp.broadcast_to(state.centroids[best], state.centroids.shape)
        base_d = jnp.broadcast_to(state.degenerate[best], state.degenerate.shape)
    coop_b = jnp.broadcast_to(coop, (w,))
    base_c = jnp.where(coop_b[:, None, None], base_c, state.centroids)
    base_d = jnp.where(coop_b[:, None], base_d, state.degenerate)
    return base_c, base_d


def _coop_flag(r: Array, cfg: HPClustConfig) -> Array:
    if cfg.strategy in ("inner", "sequential", "competitive"):
        return jnp.bool_(False)
    if cfg.strategy == "cooperative":
        return jnp.bool_(True)
    return r >= cfg.effective_t1  # hybrid / hybrid2


def _cross_group_sync(state: WorkerState, r: Array, cfg: HPClustConfig) -> WorkerState:
    """hybrid2: every sync_every rounds, the global best replaces each
    group's *worst* incumbent (keeps diversity; beyond-paper)."""
    if cfg.strategy != "hybrid2" or cfg.groups <= 1:
        return state
    g, per = cfg.groups, cfg.workers // cfg.groups
    do = (r + 1) % cfg.sync_every == 0
    safe_obj = _mask_nonfinite(state.best_obj)
    gbest = jnp.argmin(safe_obj)
    obj_g = safe_obj.reshape(g, per)
    worst_in_group = jnp.argmax(obj_g, axis=1) + jnp.arange(g) * per  # (g,)
    replace = jnp.zeros((cfg.workers,), jnp.bool_).at[worst_in_group].set(True)
    # Don't overwrite the global best itself.
    replace = replace & (jnp.arange(cfg.workers) != gbest) & do
    new_c = jnp.where(replace[:, None, None], state.centroids[gbest], state.centroids)
    new_o = jnp.where(replace, state.best_obj[gbest], state.best_obj)
    new_d = jnp.where(replace[:, None], state.degenerate[gbest], state.degenerate)
    return WorkerState(new_c, new_o, new_d, state.key)


def run_rounds(
    state: WorkerState,
    data: Array,
    cfg: HPClustConfig,
) -> tuple[WorkerState, RoundMetrics]:
    """Run ``cfg.rounds`` HPClust rounds over a device-resident window,
    continuing from ``state`` (incumbents persist across stream windows —
    the MSSC-ITD semantics).

    ``data`` is the current reservoir window of the (conceptually infinite)
    stream: (m, d). Each worker draws an independent uniform sample of size
    ``cfg.sample_size`` per round (with replacement — m >> s in the ITD
    regime, see DESIGN.md).
    """
    m, _ = data.shape

    def round_fn(state: WorkerState, r: Array):
        # named_scope labels survive into HLO metadata, so XLA profiles of
        # the scanned round body stay attributable to algorithm phases.
        with jaxhooks.named_scope("round.quarantine"):
            state, quarantined = quarantine_nonfinite(state)
        with jaxhooks.named_scope("round.select_base"):
            coop = _coop_flag(r, cfg)
            base_c, base_deg = _select_base(state, coop, cfg)
        with jaxhooks.named_scope("round.sample"):
            keys = jax.vmap(lambda kk: jax.random.split(kk))(state.key)
            sample_keys, next_keys = keys[:, 0], keys[:, 1]
            idx = jax.vmap(
                lambda kk: jax.random.randint(kk, (cfg.sample_size,), 0, m)
            )(sample_keys)
            samples = data[idx]  # (W, s, d)
        with jaxhooks.named_scope("round.worker_round"):
            new_c, new_obj, new_deg, keys2, accepted, iters = jax.vmap(
                lambda c, o, dg, kk, bc, bd, sm: _worker_round(
                    c, o, dg, kk, bc, bd, sm, cfg
                )
            )(
                state.centroids,
                state.best_obj,
                state.degenerate,
                next_keys,
                base_c,
                base_deg,
                samples,
            )
        new_state = WorkerState(new_c, new_obj, new_deg, keys2)
        with jaxhooks.named_scope("round.cross_group_sync"):
            new_state = _cross_group_sync(new_state, r, cfg)
        return new_state, RoundMetrics(
            new_state.best_obj, accepted, iters, quarantined
        )

    return jax.lax.scan(round_fn, state, jnp.arange(cfg.rounds))


def run_hpclust(
    key: Array,
    data: Array,
    cfg: HPClustConfig,
) -> tuple[WorkerState, RoundMetrics]:
    """Fresh run: init all-degenerate worker states, then run_rounds."""
    key, k_init = jax.random.split(key)
    state = init_state(k_init, cfg, data.shape[1])
    return run_rounds(state, data, cfg)


def best_of(state: WorkerState) -> tuple[Array, Array]:
    """Algorithm 3 line 21: centroids of the worker with minimum \\hat f_w.

    Non-finite incumbents (poisoned workers) are masked out of the argmin."""
    w = jnp.argmin(_mask_nonfinite(state.best_obj))
    return state.centroids[w], state.best_obj[w]
