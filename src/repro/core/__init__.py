"""HPClust core: the paper's contribution as composable JAX modules.

Submodules: kmeans, kmeanspp, strategies, hpclust, baselines, sharded.
(Function names are not re-exported at package level to avoid shadowing the
submodule names.)
"""
from repro.core.hpclust import HPClust, HPClustResult
from repro.core.strategies import HPClustConfig, WorkerState, best_of

__all__ = ["HPClust", "HPClustResult", "HPClustConfig", "WorkerState", "best_of"]
