"""HPClust on the production mesh: shard_map SPMD implementation.

Mesh mapping (DESIGN.md SS4):
  * workers              <-> the ``data`` axis (and ``pod`` x ``data`` on the
                             multi-pod mesh) — competitive/cooperative tier;
  * inner parallelism    <-> the ``model`` axis — each worker's sample (and
                             its reservoir shard) is split 16 ways; distance
                             evaluation is local, centroid updates and
                             objectives are ``psum`` over ``model``.

Everything that Algorithms 3-5 do with locks becomes a collective:

  keep-the-best            pure jnp.where per worker group
  cooperative best select  pmin(objective) + owner-masked psum of centroids
  K-means++ / reseed       *global* D^2 categorical draws via the Gumbel-max
                           trick: a psum/pmax over the ``model`` axis turns
                           per-shard maxima into an exact global categorical
                           sample — no gather, no host round-trip
  hybrid T1/T2             static round-count split of a lax.scan
  hybrid2 (beyond paper)   cooperative psum over ('data',) every round, and
                           over ('pod','data') every ``sync_every`` rounds

The Lloyd loop uses the fixed-trip-count variant (kmeans logic inlined with
done-masking): a static schedule keeps the SPMD collective program uniform
across worker groups. See DESIGN.md SS2 for why this replaces the paper's
convergence-triggered exit.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.strategies import HPClustConfig
from repro.obs import jaxhooks

Array = jax.Array
_INT_MAX = jnp.iinfo(jnp.int32).max


class ShardedState(NamedTuple):
    """Worker incumbents, leading axis = workers (sharded over worker axes).

    Beyond the incumbents themselves the state carries everything a restart
    needs (the elastic/resumable contract, mirroring the single-host
    ``WorkerState``):

      * ``key`` — per-worker-group PRNG keys. Round keys derive as
        ``fold_in(key_w, rounds_done + r)``, so a run restored from a
        checkpoint replays the exact sample draws the uninterrupted run
        would have made (bit-for-bit on the same mesh).
      * ``alive`` — host-controlled liveness mask. A dead worker group is
        frozen: it never accepts a round result, contributes ``+inf`` to
        every cooperative/hybrid2 selection, and never receives the global
        best. The launcher flips this for quarantined groups on a degraded
        mesh (see ``repro.launch.elastic``).
      * ``rounds_done`` — global round counter (scalar), the PRNG offset.
    """

    centroids: Array    # (W, k, d) f32
    best_obj: Array     # (W,) f32
    degenerate: Array   # (W, k) bool
    key: Array          # (W, 2) uint32 per-worker-group PRNG
    alive: Array        # (W,) bool liveness mask
    rounds_done: Array  # () int32 global round counter


# ---------------------------------------------------------------------------
# collective helpers (all run *inside* shard_map)
# ---------------------------------------------------------------------------

def _owner_mask(value: Array, axes, sizes: dict, *, select_min: bool) -> Array:
    """Boolean: is this device('s group) the unique arg-extremum over axes?

    Ties broken towards the lowest flat axis index, so exactly one group
    wins. ``sizes`` carries the static mesh axis sizes (older jax has no
    ``lax.axis_size``; the mesh is static anyway).
    """
    best = jax.lax.pmin(value, axes) if select_min else jax.lax.pmax(value, axes)
    cand = value <= best if select_min else value >= best
    idx = jnp.int32(0)
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    for ax in axes_t:
        idx = idx * sizes[ax] + jax.lax.axis_index(ax)
    owner_idx = jax.lax.pmin(jnp.where(cand, idx, _INT_MAX), axes)
    return cand & (idx == owner_idx)


def _broadcast_from_owner(tree, owner: Array, axes):
    """psum of owner-masked values == broadcast of the owner's values."""
    return jax.tree.map(
        lambda v: jax.lax.psum(
            jnp.where(
                owner.astype(jnp.bool_).reshape((1,) * v.ndim),
                v.astype(jnp.float32),
                0.0,
            ),
            axes,
        ),
        tree,
    )


def _global_categorical_row(
    key: Array, weights: Array, x: Array, axis: str, sizes: dict
):
    """One global categorical draw (prob ∝ weights) over rows sharded on
    ``axis``; returns the winning row of x. Gumbel-max: global argmax of
    log w + Gumbel noise is an exact categorical sample."""
    g = jax.random.gumbel(key, weights.shape, dtype=jnp.float32)
    val = jnp.log(jnp.maximum(weights, 1e-30)) + g
    lmax = jnp.max(val)
    larg = jnp.argmax(val)
    owner = _owner_mask(lmax, axis, sizes, select_min=False)
    row = jnp.where(owner, x[larg], jnp.zeros_like(x[larg]))
    return jax.lax.psum(row, axis)


# ---------------------------------------------------------------------------
# sharded K-means++ reseed + Lloyd
# ---------------------------------------------------------------------------

def _sq_dists_to_point(x: Array, p: Array) -> Array:
    diff = x - p[None, :]
    return jnp.sum(diff * diff, axis=-1)


def _reseed_degenerate_sharded(
    key: Array, x: Array, c: Array, mask: Array, cfg: HPClustConfig,
    inner_axis: str, sizes: dict,
) -> Array:
    """reseed_degenerate with x sharded over inner_axis (global D^2 draws)."""
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    d2 = (
        jnp.sum(xf * xf, axis=1, keepdims=True)
        - 2.0 * xf @ cf.T
        + jnp.sum(cf * cf, axis=1)[None, :]
    )
    d2 = jnp.maximum(d2, 0.0)
    d2 = jnp.where(mask[None, :], jnp.inf, d2)
    mind = jnp.min(d2, axis=1)
    mind = jnp.where(jnp.isinf(mind), 1.0, mind)
    # Decorrelate gumbel noise across inner shards (global draw needs iid
    # noise per *global* row).
    key = jax.random.fold_in(key, jax.lax.axis_index(inner_axis))

    def body(j, state):
        cc, mind, key = state
        key, kj = jax.random.split(key)
        cand_keys = jax.random.split(kj, cfg.n_candidates)
        cands = jnp.stack(
            [
                _global_categorical_row(
                    cand_keys[l], mind, xf, inner_axis, sizes)
                for l in range(cfg.n_candidates)
            ]
        )  # (L, d)
        cand_d2 = jax.vmap(lambda p: _sq_dists_to_point(xf, p))(cands)  # (L, s_loc)
        new_minds = jnp.minimum(mind[None, :], cand_d2)
        potentials = jax.lax.psum(jnp.sum(new_minds, axis=1), inner_axis)  # (L,)
        best = jnp.argmin(potentials)
        # Masked (static-shape) update: no lax.cond so the collective
        # schedule is identical on every worker group.
        new_c_j = jnp.where(mask[j], cands[best], cc[j])
        new_mind_if_live = jnp.minimum(mind, _sq_dists_to_point(xf, cc[j]))
        new_mind = jnp.where(mask[j], new_minds[best], new_mind_if_live)
        return cc.at[j].set(new_c_j), new_mind, key

    cc, _, _ = jax.lax.fori_loop(0, cfg.k, body, (cf, mind, key))
    return cc


def _assign_local(x: Array, c: Array):
    """Local nearest-centroid assignment (s_loc, k) — inner-parallel tier."""
    xf, cf = x.astype(jnp.float32), c.astype(jnp.float32)
    d2 = (
        jnp.sum(xf * xf, axis=1, keepdims=True)
        - 2.0 * xf @ cf.T
        + jnp.sum(cf * cf, axis=1)[None, :]
    )
    d2 = jnp.maximum(d2, 0.0)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist = jnp.min(d2, axis=1)
    return idx, dist


def _lloyd_sharded(
    x: Array, c0: Array, cfg: HPClustConfig, inner_axis: str
):
    """Fixed-schedule Lloyd with psum(sums, counts, obj) over the inner axis."""
    k = cfg.k

    def one(c):
        idx, dist = _assign_local(x, c)
        onehot = jax.nn.one_hot(idx, k, dtype=jnp.float32)
        sums = jax.lax.psum(onehot.T @ x.astype(jnp.float32), inner_axis)
        counts = jax.lax.psum(jnp.sum(onehot, axis=0), inner_axis)
        obj = jax.lax.psum(jnp.sum(dist), inner_axis)
        new_c = jnp.where(
            (counts == 0)[:, None], c, sums / jnp.maximum(counts, 1.0)[:, None]
        )
        return new_c, obj, counts

    def body(_, state):
        c, prev_obj, done, _ = state
        new_c, obj, counts = one(c)
        improved = (prev_obj - obj) > cfg.kmeans_tol * jnp.maximum(obj, 1e-30)
        now_done = done | ~improved
        return (
            jnp.where(done, c, new_c),
            jnp.where(done, prev_obj, obj),
            now_done,
            counts,
        )

    iters = min(cfg.kmeans_iters, 64)
    c0 = c0.astype(jnp.float32)
    zero_counts = jnp.zeros((k,), jnp.float32)
    c, _, _, _ = jax.lax.fori_loop(
        0, iters, body, (c0, jnp.inf, jnp.bool_(False), zero_counts)
    )
    # Final stats under returned centroids.
    _, obj, counts = one(c)
    return c, obj, counts


# ---------------------------------------------------------------------------
# the sharded round loop
# ---------------------------------------------------------------------------

def _rounds_body(
    centroids: Array,   # (1, k, d) local worker shard
    best_obj: Array,    # (1,)
    degenerate: Array,  # (1, k)
    keys: Array,        # (1, 2) this worker group's PRNG key
    alive: Array,       # (1,) liveness mask
    rounds_done: Array, # () global round counter (replicated)
    reservoir: Array,   # (1, m_shard, d) local slice of this worker's reservoir
    *,
    cfg: HPClustConfig,
    worker_axes: tuple[str, ...],
    inner_axis: str,
    pod_axis: str | None,
    sizes: dict,
):
    c = centroids[0]
    obj = best_obj[0]
    deg = degenerate[0]
    key = keys[0]
    live = alive[0]
    res = reservoir[0]
    m_shard = res.shape[0]
    s_loc = max(1, cfg.sample_size // sizes[inner_axis])

    iidx = jax.lax.axis_index(inner_axis)

    intra_axes: tuple[str, ...] = tuple(a for a in worker_axes if a != pod_axis)
    all_axes = worker_axes

    def coop_best(c, obj, deg, axes):
        # Poisoned incumbents (NaN/-inf) must never own the broadcast: mask
        # to +inf before the pmin/owner selection (mirrors strategies.py).
        # Dead worker groups (liveness mask) contribute +inf too, so a
        # quarantined group's stale incumbent can never warm-start anyone.
        obj = jnp.where(live & jnp.isfinite(obj), obj, jnp.inf)
        owner = _owner_mask(obj, axes, sizes, select_min=True)
        best_c, best_deg = _broadcast_from_owner((c, deg.astype(jnp.float32)), owner, axes)
        return best_c, jax.lax.pmin(obj, axes), best_deg > 0.5

    def round_fn(carry, r):
        c, obj, deg = carry
        # Quarantine (device-local, no collectives): a poisoned incumbent
        # resets to the virgin all-degenerate state so the next reseed
        # redraws every centroid row from the live sample.
        with jaxhooks.named_scope("round.quarantine"):
            bad = jnp.isnan(obj) | (obj == -jnp.inf) | ~jnp.all(jnp.isfinite(c))
            c = jnp.where(bad, jnp.zeros_like(c), c)
            obj = jnp.where(bad, jnp.inf, obj)
            deg = jnp.where(bad, jnp.ones_like(deg), deg)
        # Global round numbering: a resumed run folds in the same indices the
        # uninterrupted one would have, so replay is bit-for-bit.
        rkey = jax.random.fold_in(key, rounds_done + r)
        k_samp, k_seed = jax.random.split(rkey)

        # --- coordination: choose the warm start -------------------------
        with jaxhooks.named_scope("round.coop_select"):
            if cfg.strategy in ("inner", "sequential", "competitive"):
                base_c, base_deg = c, deg
            elif cfg.strategy == "cooperative":
                base_c, _, base_deg = coop_best(c, obj, deg, all_axes)
            elif cfg.strategy == "hybrid":
                bc, _, bd = coop_best(c, obj, deg, all_axes)
                coop = r >= cfg.effective_t1
                base_c = jnp.where(coop, bc, c)
                base_deg = jnp.where(coop, bd, deg)
            else:  # hybrid2: intra-pod every round, cross-pod every sync_every
                bc, _, bd = coop_best(c, obj, deg, intra_axes)
                coop = r >= cfg.effective_t1
                base_c = jnp.where(coop, bc, c)
                base_deg = jnp.where(coop, bd, deg)

        # --- sample: stratified over the inner axis ----------------------
        with jaxhooks.named_scope("round.sample"):
            k_samp_loc = jax.random.fold_in(k_samp, iidx)
            idx = jax.random.randint(k_samp_loc, (s_loc,), 0, m_shard)
            sample = res[idx]  # (s_loc, d)

        # --- reseed degenerate + Lloyd ------------------------------------
        with jaxhooks.named_scope("round.reseed"):
            seeded = _reseed_degenerate_sharded(
                k_seed, sample, base_c, base_deg, cfg, inner_axis, sizes
            )
        with jaxhooks.named_scope("round.lloyd"):
            new_c, new_obj, counts = _lloyd_sharded(
                sample, seeded, cfg, inner_axis)

        # --- keep the best -------------------------------------------------
        # Non-finite candidates never displace the incumbent (-inf would
        # otherwise win the compare and poison every later coop round).
        # Dead worker groups are frozen: their results are untrusted, so
        # they never accept — the incumbent they carried stays intact for
        # a later host-side revive/redistribution.
        accept = (new_obj < obj) & jnp.isfinite(new_obj) & live
        c2 = jnp.where(accept, new_c, c)
        o2 = jnp.where(accept, new_obj, obj)
        d2_ = jnp.where(accept, counts == 0, deg)

        # --- hybrid2 cross-pod sync (rare, DCI-budgeted) -------------------
        if cfg.strategy == "hybrid2" and pod_axis is not None:
            do = (r + 1) % cfg.sync_every == 0
            gc, go, gd = coop_best(c2, o2, d2_, all_axes)
            # Replace the per-pod *worst* incumbent with the global best
            # (non-finite incumbents count as worst, so they are replaced;
            # dead groups map to -inf so they never win worst — the global
            # best must not be parked on a quarantined device).
            o2_safe = jnp.where(jnp.isfinite(o2), o2, jnp.inf)
            o2_cand = jnp.where(live, o2_safe, -jnp.inf)
            worst = _owner_mask(o2_cand, intra_axes, sizes, select_min=False)
            better = go < o2_safe
            take = do & worst & better & live
            c2 = jnp.where(take, gc, c2)
            o2 = jnp.where(take, go, o2)
            d2_ = jnp.where(take, gd, d2_)

        return (c2, o2, d2_), o2

    (c, obj, deg), objs = jax.lax.scan(
        round_fn, (c, obj, deg), jnp.arange(cfg.rounds)
    )
    new_rounds_done = (rounds_done + cfg.rounds).astype(jnp.int32)
    return (c[None], obj[None], deg[None], keys, alive,
            new_rounds_done, objs[:, None])


def build_sharded_runner(
    mesh: Mesh,
    cfg: HPClustConfig,
    *,
    inner_axis: str = "model",
    pod_axis: str | None = None,
):
    """Returns (fn, in_shardings, out_shardings) for the mesh.

    fn(state, reservoir) -> (state', per-round objectives (rounds, W)).

    Worker axes are every mesh axis except the inner one; ``cfg.workers``
    must equal their product. Reservoir: (W, m_shard_total, d) sharded
    (workers, inner, -). PRNG keys ride in the state (one per worker
    group), so successive calls — and calls resumed from a checkpoint —
    continue one deterministic stream of rounds.
    """
    worker_axes = tuple(a for a in mesh.axis_names if a != inner_axis)
    n_workers = 1
    for a in worker_axes:
        n_workers *= mesh.shape[a]
    if cfg.workers != n_workers:
        raise ValueError(
            f"cfg.workers={cfg.workers} must equal prod(worker axes)={n_workers}"
        )
    if pod_axis is not None and pod_axis not in worker_axes:
        raise ValueError(f"pod_axis {pod_axis} not in {worker_axes}")

    wspec = P(worker_axes)
    specs = dict(
        centroids=P(worker_axes, None, None),
        best_obj=wspec,
        degenerate=P(worker_axes, None),
        key=P(worker_axes, None),
        alive=wspec,
        rounds_done=P(),
        reservoir=P(worker_axes, inner_axis, None),
    )
    state_specs = ShardedState(
        centroids=specs["centroids"],
        best_obj=specs["best_obj"],
        degenerate=specs["degenerate"],
        key=specs["key"],
        alive=specs["alive"],
        rounds_done=specs["rounds_done"],
    )

    body = functools.partial(
        _rounds_body,
        cfg=cfg,
        worker_axes=worker_axes,
        inner_axis=inner_axis,
        pod_axis=pod_axis,
        sizes=dict(mesh.shape),
    )
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(state_specs) + (specs["reservoir"],),
        out_specs=tuple(state_specs) + (P(None, worker_axes),),
        check_rep=False,
    )

    def fn(state: ShardedState, reservoir: Array):
        rd = jnp.asarray(state.rounds_done, jnp.int32)
        c, o, d, k, a, r, objs = mapped(
            state.centroids, state.best_obj, state.degenerate,
            state.key, state.alive, rd, reservoir,
        )
        return ShardedState(c, o, d, k, a, r), objs

    state_shardings = ShardedState(
        *(NamedSharding(mesh, s) for s in state_specs)
    )
    in_shardings = (
        state_shardings,
        NamedSharding(mesh, specs["reservoir"]),
    )
    out_shardings = (
        state_shardings,
        NamedSharding(mesh, P(None, worker_axes)),
    )
    return fn, in_shardings, out_shardings


def init_sharded_state(
    cfg: HPClustConfig, d: int, *, seed: int = 0
) -> ShardedState:
    """Virgin state: all centroids degenerate, objectives +inf, all groups
    alive, one independent PRNG stream per worker group."""
    return ShardedState(
        centroids=jnp.zeros((cfg.workers, cfg.k, d), jnp.float32),
        best_obj=jnp.full((cfg.workers,), jnp.inf, jnp.float32),
        degenerate=jnp.ones((cfg.workers, cfg.k), jnp.bool_),
        key=jax.random.split(jax.random.PRNGKey(seed), cfg.workers),
        alive=jnp.ones((cfg.workers,), jnp.bool_),
        rounds_done=jnp.zeros((), jnp.int32),
    )


def state_shapes(cfg: HPClustConfig, d: int) -> ShardedState:
    """ShapeDtypeStructs matching ``init_sharded_state`` (for AOT lowering)."""
    w = cfg.workers
    return ShardedState(
        centroids=jax.ShapeDtypeStruct((w, cfg.k, d), jnp.float32),
        best_obj=jax.ShapeDtypeStruct((w,), jnp.float32),
        degenerate=jax.ShapeDtypeStruct((w, cfg.k), jnp.bool_),
        key=jax.ShapeDtypeStruct((w, 2), jnp.uint32),
        alive=jax.ShapeDtypeStruct((w,), jnp.bool_),
        rounds_done=jax.ShapeDtypeStruct((), jnp.int32),
    )


def mark_dead(state: ShardedState, groups) -> ShardedState:
    """Host-side quarantine: flip the liveness mask off for ``groups``.

    A dead group is frozen by the engine (never accepts, contributes +inf
    to every cooperative selection) until revived or redistributed away.
    """
    alive = np.array(jax.device_get(state.alive), copy=True)
    alive[list(groups)] = False
    return state._replace(alive=jnp.asarray(alive))


def revive(state: ShardedState, groups=None) -> ShardedState:
    """Undo ``mark_dead`` for ``groups`` (default: every group)."""
    alive = np.array(jax.device_get(state.alive), copy=True)
    alive[list(groups) if groups is not None else slice(None)] = True
    return state._replace(alive=jnp.asarray(alive))


def best_of(state: ShardedState) -> tuple[Array, Array]:
    """Centroids/objective of the best *live* worker group (dead and
    non-finite incumbents are masked out of the argmin)."""
    obj = jnp.where(
        state.alive & jnp.isfinite(state.best_obj), state.best_obj, jnp.inf
    )
    w = jnp.argmin(obj)
    return state.centroids[w], obj[w]
