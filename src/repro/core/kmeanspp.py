"""K-means++ seeding and degenerate-cluster re-seeding (paper SS3, SS6.5).

The paper's K-means++ samples each new centroid by D^2-weighting with
``n_candidates = 3`` greedy candidates (SS6.5, following sklearn/Arthur &
Vassilvitskii's greedy variant): draw 3 candidates proportional to the
current nearest-centroid distances, keep the one that lowers the potential
most.

``reseed_degenerate`` generalizes the same primitive: given a centroid set
with a boolean mask of degenerate (empty) clusters, re-draw exactly the
masked rows by D^2 sampling against the *live* rows. K-means++ from scratch
is the special case where every row is masked — which is exactly how HPClust
initializes round 0 (Algorithms 3-5 start with "all centroids degenerate").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _sq_dists_to_point(x: Array, p: Array) -> Array:
    diff = x.astype(jnp.float32) - p.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)


def _draw_candidates(key: Array, weights: Array, n: int) -> Array:
    """n categorical draws with prob ∝ weights, via the Gumbel-max trick.

    Gumbel-max keeps the same mechanism usable in the sharded path (a global
    argmax over device shards == a global categorical draw), so the host and
    distributed implementations are bit-comparable in structure.
    """
    logits = jnp.log(jnp.maximum(weights, 1e-30))
    g = jax.random.gumbel(key, (n,) + weights.shape, dtype=jnp.float32)
    return jnp.argmax(logits[None, :] + g, axis=-1)


def reseed_degenerate(
    key: Array,
    x: Array,
    c: Array,
    mask: Array,
    *,
    n_candidates: int = 3,
) -> Array:
    """Replace masked centroid rows by greedy D^2-sampled points of ``x``.

    Args:
      key: PRNG key.
      x: (s, d) sample.
      c: (k, d) current centroids (masked rows' values are ignored).
      mask: (k,) bool — True rows are degenerate and get re-drawn.
    Returns:
      (k, d) f32 centroids with masked rows replaced.
    """
    s = x.shape[0]
    k = c.shape[0]
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    # mind_i = distance to the nearest *live* centroid; all-masked => uniform.
    d2 = (
        jnp.sum(xf * xf, axis=1, keepdims=True)
        - 2.0 * xf @ cf.T
        + jnp.sum(cf * cf, axis=1)[None, :]
    )  # (s, k)
    d2 = jnp.maximum(d2, 0.0)
    d2 = jnp.where(mask[None, :], jnp.inf, d2)
    mind = jnp.min(d2, axis=1)
    mind = jnp.where(jnp.isinf(mind), 1.0, mind)  # no live centroid yet

    def body(j, state):
        cc, mind, key = state
        key, kd = jax.random.split(key)

        def redraw(args):
            cc, mind, kd = args
            cand_idx = _draw_candidates(kd, mind, n_candidates)  # (L,)
            cands = xf[cand_idx]  # (L, d)
            cand_d2 = jax.vmap(lambda p: _sq_dists_to_point(xf, p))(cands)  # (L, s)
            new_minds = jnp.minimum(mind[None, :], cand_d2)  # (L, s)
            potentials = jnp.sum(new_minds, axis=1)  # (L,)
            best = jnp.argmin(potentials)
            cc = cc.at[j].set(cands[best])
            return cc, new_minds[best]

        def keep(args):
            cc, mind, _ = args
            # Live centroid: fold its own distance into mind so subsequent
            # draws are D^2 w.r.t. the full live set (matters when the
            # initial mask was all-True: rows seeded earlier become live).
            return cc, jnp.minimum(mind, _sq_dists_to_point(xf, cc[j]))

        cc, mind = jax.lax.cond(mask[j], redraw, keep, (cc, mind, kd))
        return cc, mind, key

    # For a from-scratch init (all masked), mind against "live" rows is the
    # uniform vector above, so row 0 is a uniform draw — exactly k-means++.
    cc, _, _ = jax.lax.fori_loop(0, k, body, (cf, mind, key))
    return cc


def kmeanspp(key: Array, x: Array, k: int, *, n_candidates: int = 3) -> Array:
    """Greedy K-means++ seeding of k centroids from sample x (s, d)."""
    d = x.shape[1]
    c = jnp.zeros((k, d), jnp.float32)
    return reseed_degenerate(
        key, x, c, jnp.ones((k,), jnp.bool_), n_candidates=n_candidates
    )
