"""Baseline algorithms the paper compares against (SS6.2).

* Forgy K-means  (Algorithm 1)  — full-data Lloyd from a uniform-random seed.
* PBK-BDC        (Algorithm 2)  — partition X into segments of size p,
  K-means each, pool the centroids, K-means the pool, final assign.
* Minibatch K-means (Sculley 2010, paper SS2) — per-batch SGD centroid update
  with per-center counts; an extra lower baseline.

All are batched so the "big data" datasets of the scaling experiment never
materialize an (m, k) distance matrix.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans as km  # module import (package does not re-export the fn)
from repro.kernels import ops

Array = jax.Array

# Jitted once at import (analysis JH003): the per-call wrappers these replace
# keyed the compile cache on a fresh lambda identity, re-tracing every call.
_jit_kmeans = jax.jit(km.kmeans, static_argnames=("max_iters", "tol", "impl"))
_jit_objective = jax.jit(ops.mssc_objective, static_argnames=("impl",))


class BaselineResult(NamedTuple):
    centroids: np.ndarray
    objective: float
    iterations: int


def _full_objective(x: np.ndarray, c: Array, impl, batch: int = 1 << 17) -> float:
    c = jnp.asarray(c)
    return sum(
        float(_jit_objective(jnp.asarray(x[i : i + batch]), c, impl=impl))
        for i in range(0, len(x), batch)
    )


def forgy_kmeans(
    x: np.ndarray,
    k: int,
    *,
    seed: int = 0,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str | None = None,
) -> BaselineResult:
    """Algorithm 1: uniform-random initial centroids + Lloyd to convergence."""
    rng = np.random.default_rng(seed)
    c0 = jnp.asarray(x[rng.choice(len(x), size=k, replace=False)], jnp.float32)
    res = _jit_kmeans(
        jnp.asarray(x, jnp.float32), c0, max_iters=max_iters, tol=tol, impl=impl
    )
    return BaselineResult(
        np.asarray(res.centroids), float(res.objective), int(res.iterations)
    )


def pbk_bdc(
    x: np.ndarray,
    k: int,
    *,
    segment_size: int = 4096,
    seed: int = 0,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str | None = None,
) -> BaselineResult:
    """Algorithm 2 (Alguliyev et al. 2021).

    Segments are clustered with K-means (Forgy seeds), their centroids pooled
    into repository P, which is clustered again; final objective is evaluated
    on the full dataset.
    """
    rng = np.random.default_rng(seed)
    m = len(x)
    n_seg = max(1, m // segment_size)
    perm = rng.permutation(m)

    def run(xx, cc):
        return _jit_kmeans(xx, cc, max_iters=max_iters, tol=tol, impl=impl)

    pool = []
    iters = 0
    for si in range(n_seg):
        seg = x[perm[si * segment_size : (si + 1) * segment_size]]
        if len(seg) < k:
            continue
        c0 = jnp.asarray(seg[rng.choice(len(seg), size=k, replace=False)], jnp.float32)
        res = run(jnp.asarray(seg, jnp.float32), c0)
        pool.append(np.asarray(res.centroids))
        iters += int(res.iterations)
    p = np.concatenate(pool, axis=0)
    c0 = jnp.asarray(p[rng.choice(len(p), size=k, replace=False)], jnp.float32)
    res = run(jnp.asarray(p, jnp.float32), c0)
    obj = _full_objective(x, res.centroids, impl)
    return BaselineResult(np.asarray(res.centroids), obj, iters + int(res.iterations))


def minibatch_kmeans(
    x: np.ndarray,
    k: int,
    *,
    batch_size: int = 1024,
    steps: int = 100,
    seed: int = 0,
    impl: str | None = None,
) -> BaselineResult:
    """Sculley's web-scale K-means: per-center learning rates 1/n_c."""
    rng = np.random.default_rng(seed)
    c = jnp.asarray(x[rng.choice(len(x), size=k, replace=False)], jnp.float32)
    counts = jnp.zeros((k,), jnp.float32)

    for _ in range(steps):
        xb = jnp.asarray(x[rng.integers(0, len(x), size=batch_size)], jnp.float32)
        c, counts = _minibatch_step(c, counts, xb, k=k, impl=impl)
    obj = _full_objective(x, c, impl)
    return BaselineResult(np.asarray(c), obj, steps)


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def _minibatch_step(c, counts, xb, *, k: int, impl: str | None):
    idx, _ = ops.assign_clusters(xb, c, impl=impl)
    sums, n = ops.cluster_sums(xb, idx, k, impl=impl)
    new_counts = counts + n
    lr = jnp.where(n > 0, n / jnp.maximum(new_counts, 1.0), 0.0)[:, None]
    target = sums / jnp.maximum(n, 1.0)[:, None]
    return c + lr * (target - c), new_counts
