"""Lloyd's K-means local search (Algorithm 1's iterative core), in JAX.

This is the inner optimizer every HPClust worker applies to each sample
(paper SS3). Stopping rule follows the paper's SS6.5: at most ``max_iters``
iterations (300 in the paper) or relative objective improvement below ``tol``
(1e-4 in the paper).

Two loop flavours:
  * ``kmeans``        — ``lax.while_loop`` with true early exit (host/vmap path).
  * ``kmeans_fixed``  — ``lax.fori_loop`` with a fixed trip count and
    convergence-masked updates. Used by the shard_map'd distributed path: a
    static schedule keeps every device of a worker group on the same
    iteration count, which makes the SPMD program uniform and the collective
    schedule static (TPU adaptation; see DESIGN.md SS2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

Array = jax.Array


class KMeansResult(NamedTuple):
    centroids: Array  # (k, d) f32
    objective: Array  # () f32 — f(C, S) under the returned centroids
    counts: Array     # (k,) f32 cluster sizes under the returned centroids
    iterations: Array # () int32


def lloyd_iteration(x: Array, c: Array, *, impl: str | None = None):
    """One assign+update step.

    Returns (new_c, obj_under_c, counts, degenerate_mask). Empty clusters
    keep their previous centroid and are flagged degenerate (paper SS3 re-seeds
    them with K-means++ at the *next* sample).
    """
    k = c.shape[0]
    idx, dist = ops.assign_clusters(x, c, impl=impl)
    sums, counts = ops.cluster_sums(x, idx, k, impl=impl)
    degenerate = counts == 0
    new_c = jnp.where(
        degenerate[:, None],
        c.astype(jnp.float32),
        sums / jnp.maximum(counts, 1.0)[:, None],
    )
    return new_c, jnp.sum(dist), counts, degenerate


def kmeans(
    x: Array,
    c0: Array,
    *,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str | None = None,
) -> KMeansResult:
    """Lloyd iterations with early exit on relative improvement < tol."""

    def cond(state):
        _, prev_obj, obj, it = state
        # Relative-improvement test on the *current* objective so the inf
        # sentinel in prev_obj can't poison the threshold (inf - x > inf is
        # False, which would exit after one iteration).
        improving = (prev_obj - obj) > tol * jnp.maximum(obj, 1e-30)
        return jnp.logical_and(it < max_iters, improving)

    def body(state):
        c, _, obj, it = state
        new_c, obj_under_c, _, _ = lloyd_iteration(x, c, impl=impl)
        return new_c, obj, obj_under_c, it + 1

    c0 = c0.astype(jnp.float32)
    # Prime the loop with one real iteration so `obj` is meaningful.
    c1, obj0, _, _ = lloyd_iteration(x, c0, impl=impl)
    c, _, _, iters = jax.lax.while_loop(cond, body, (c1, jnp.inf, obj0, jnp.int32(1)))
    # Final stats under the returned centroids (what the incumbent compare uses).
    _, obj, counts, _ = lloyd_iteration(x, c, impl=impl)
    return KMeansResult(c, obj, counts, iters)


def kmeans_fixed(
    x: Array,
    c0: Array,
    *,
    iters: int = 32,
    tol: float = 1e-4,
    impl: str | None = None,
) -> KMeansResult:
    """Fixed-trip-count Lloyd with convergence masking (static SPMD schedule)."""

    def body(_, state):
        c, prev_obj, done = state
        new_c, obj, _, _ = lloyd_iteration(x, c, impl=impl)
        improved = (prev_obj - obj) > tol * jnp.maximum(obj, 1e-30)
        now_done = jnp.logical_or(done, jnp.logical_not(improved))
        c = jnp.where(done, c, new_c)
        prev_obj = jnp.where(done, prev_obj, obj)
        return c, prev_obj, now_done

    c0 = c0.astype(jnp.float32)
    c, _, _ = jax.lax.fori_loop(0, iters, body, (c0, jnp.inf, jnp.bool_(False)))
    _, obj, counts, _ = lloyd_iteration(x, c, impl=impl)
    return KMeansResult(c, obj, counts, jnp.int32(iters))
