"""DT: dtype discipline — f32 end to end, explicit MXU accumulation.

JAX defaults to f32 (x64 disabled), so a float64 request is at best a silent
downcast and at worst — with x64 enabled for debugging — a 2x memory/compute
regression in the hot loop. Inside Pallas kernel bodies the MXU contracts
additionally need an explicit ``preferred_element_type``: without it a bf16
matmul accumulates in bf16 and the online argmin carry loses ties.

Codes:
  DT001  float64 dtype reference (attribute or string literal)
  DT002  dot_general/matmul in a kernel body without preferred_element_type
"""
from __future__ import annotations

import ast

from repro.analysis import astutils as au
from repro.analysis.core import ModuleContext, register
from repro.analysis.checks_pallas import kernel_def_for, pallas_call_sites

_F64_ATTRS = ("jnp.float64", "np.float64", "numpy.float64", "jax.numpy.float64")
_CONTRACTIONS = (
    "jax.lax.dot_general", "lax.dot_general", "dot_general",
    "jnp.dot", "jnp.matmul", "jnp.einsum",
    "jax.numpy.dot", "jax.numpy.matmul", "jax.numpy.einsum",
)


@register(
    "DT001",
    "float64-leak",
    "float64 dtypes silently downcast to f32 under JAX defaults and double "
    "memory traffic when x64 is enabled — keep the pipeline f32/bf16.",
)
def check_float64(ctx: ModuleContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute):
            name = au.dotted_name(node)
            if name in _F64_ATTRS:
                yield ctx.finding(
                    "DT001", node,
                    f"`{name}` referenced — float64 is a silent f32 downcast "
                    f"under default JAX config and a 2x regression under x64",
                )
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if (
                    kw.arg == "dtype"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value == "float64"
                ):
                    yield ctx.finding(
                        "DT001", kw.value,
                        "dtype='float64' requested — keep the pipeline "
                        "f32/bf16",
                    )
            name = au.call_name(node)
            if (
                name is not None
                and name.endswith(".astype")
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == "float64"
            ):
                yield ctx.finding(
                    "DT001", node.args[0],
                    ".astype('float64') requested — keep the pipeline "
                    "f32/bf16",
                )


def _kernel_bodies(ctx: ModuleContext):
    """Pallas kernel bodies: resolved pallas_call targets, plus the
    ``*_ref``-parameter naming convention as a fallback so kernels are
    checked even when their pallas_call lives in another module."""
    seen = set()
    for site in pallas_call_sites(ctx):
        kdef, _ = kernel_def_for(site, ctx)
        if kdef is not None and kdef not in seen:
            seen.add(kdef)
            yield kdef
    for fdef in ctx.defs.values():
        if fdef in seen:
            continue
        pos = au.positional_params(fdef)
        if len(pos) >= 2 and all(p.endswith("_ref") for p in pos):
            seen.add(fdef)
            yield fdef


@register(
    "DT002",
    "mxu-accumulation-dtype",
    "MXU contractions in kernel bodies must pin preferred_element_type "
    "(f32 accumulation) or low-precision inputs accumulate in low precision.",
)
def check_preferred_element_type(ctx: ModuleContext):
    for kdef in _kernel_bodies(ctx):
        for node in ast.walk(kdef):
            if not isinstance(node, ast.Call):
                continue
            name = au.call_name(node)
            if name not in _CONTRACTIONS:
                continue
            if not au.has_kwarg(node, "preferred_element_type"):
                yield ctx.finding(
                    "DT002",
                    node,
                    f"`{name}` in kernel `{kdef.name}` has no "
                    f"preferred_element_type — pass jnp.float32 so the MXU "
                    f"accumulates in f32",
                )
