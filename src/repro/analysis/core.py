"""Check registry, finding model, and the per-module analysis driver.

A *check* is a function ``(ModuleContext) -> Iterable[Finding]`` registered
under a stable code (``PK001``, ``JH003``, ...). The driver parses each file
once, builds shared context (const env, function table, parent links), and
feeds it to every selected check. Checks are pure AST consumers — no repo
code is imported or executed, so the analyzer is safe to run on broken or
TPU-only modules from any host.

Future PRs extend the suite by registering new checks (sharding-spec
validators, collective-ordering lints) — see docs/static_analysis.md.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import os
import re
from typing import Callable, Iterable, Iterator, Optional

from repro.analysis import astutils

SEVERITIES = ("error", "warning")

# Inline suppression: ``# analysis: allow JH003`` (or a comma-separated code
# list) on the finding's anchor line or the line directly above it. Trailing
# free text after the codes is the (encouraged) justification.
_PRAGMA_RE = re.compile(
    r"#\s*analysis:\s*allow\s+([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)"
)


def pragma_allows(lines: list[str]) -> dict[int, frozenset[str]]:
    """Map 1-based line number -> codes allowed by a pragma on that line."""
    out: dict[int, frozenset[str]] = {}
    for i, ln in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(ln)
        if m:
            out[i] = frozenset(c.strip() for c in m.group(1).split(","))
    return out


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str          # e.g. "PK002"
    message: str       # human explanation with the offending values inlined
    path: str          # path as given to the analyzer (normalized, relative)
    line: int          # 1-based
    col: int           # 0-based
    snippet: str       # stripped source line — part of the fingerprint
    severity: str = "error"

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline, so findings stay
        grandfathered when unrelated edits shift the file."""
        return f"{self.path}::{self.code}::{self.snippet}"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


@dataclasses.dataclass
class ModuleContext:
    """Everything a check needs about one parsed module."""

    path: str
    source: str
    tree: ast.Module
    lines: list[str]

    @functools.cached_property
    def const_env(self) -> dict:
        return astutils.module_const_env(self.tree)

    @functools.cached_property
    def defs(self) -> dict[str, ast.FunctionDef]:
        return astutils.function_defs(self.tree)

    @functools.cached_property
    def parents(self) -> dict[ast.AST, ast.AST]:
        return astutils.parent_map(self.tree)

    @functools.cached_property
    def decorator_nodes(self) -> set[ast.AST]:
        return astutils.decorator_nodes(self.tree)

    def snippet_at(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(
        self, code: str, node: ast.AST, message: str, severity: str = "error"
    ) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(
            code=code,
            message=message,
            path=self.path,
            line=line,
            col=col,
            snippet=self.snippet_at(line),
            severity=severity,
        )


CheckFn = Callable[[ModuleContext], Iterable[Finding]]


@dataclasses.dataclass(frozen=True)
class Check:
    code: str
    name: str
    description: str
    fn: CheckFn


_REGISTRY: dict[str, Check] = {}


def register(code: str, name: str, description: str):
    """Decorator: add a check to the global registry under ``code``."""

    def deco(fn: CheckFn) -> CheckFn:
        if code in _REGISTRY:
            raise ValueError(f"duplicate check code {code}")
        _REGISTRY[code] = Check(code=code, name=name, description=description, fn=fn)
        return fn

    return deco


def all_checks() -> list[Check]:
    _load_builtin_checks()
    return [_REGISTRY[c] for c in sorted(_REGISTRY)]


def _load_builtin_checks() -> None:
    # Import for registration side effects; idempotent via sys.modules.
    from repro.analysis import (  # noqa: F401
        checks_dtype,
        checks_jit,
        checks_obs,
        checks_pallas,
        checks_sharding,
    )


def select_checks(select: Optional[Iterable[str]] = None) -> list[Check]:
    """Filter registry by exact codes or prefixes ("PK" -> all PK checks)."""
    checks = all_checks()
    if not select:
        return checks
    sel = list(select)
    picked = [
        c for c in checks if any(c.code == s or c.code.startswith(s) for s in sel)
    ]
    unknown = [
        s for s in sel if not any(c.code == s or c.code.startswith(s) for c in checks)
    ]
    if unknown:
        raise KeyError(f"unknown check selector(s): {', '.join(unknown)}")
    return picked


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def iter_python_files(paths: Iterable[str]) -> Iterator[str]:
    """Expand files/dirs to .py files, skipping caches and hidden dirs."""
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs if not d.startswith(".") and d != "__pycache__"
            )
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def analyze_file(
    path: str, checks: Optional[list[Check]] = None
) -> list[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    # Canonicalize to a cwd-relative path when possible so baseline
    # fingerprints agree between `src/`, `./src`, and absolute invocations.
    norm = os.path.normpath(path)
    rel = os.path.relpath(norm)
    if not rel.startswith(".."):
        norm = rel
    return analyze_source(source, path=norm, checks=checks)


def analyze_source(
    source: str, *, path: str = "<string>", checks: Optional[list[Check]] = None
) -> list[Finding]:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [
            Finding(
                code="XX000",
                message=f"syntax error: {e.msg}",
                path=path,
                line=e.lineno or 1,
                col=(e.offset or 1) - 1,
                snippet="",
            )
        ]
    ctx = ModuleContext(
        path=path, source=source, tree=tree, lines=source.splitlines()
    )
    out: list[Finding] = []
    for check in checks if checks is not None else all_checks():
        out.extend(check.fn(ctx))
    allows = pragma_allows(ctx.lines)
    if allows:
        out = [
            f for f in out
            if f.code not in allows.get(f.line, ())
            and f.code not in allows.get(f.line - 1, ())
        ]
    out.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return out


def analyze_paths(
    paths: Iterable[str], *, select: Optional[Iterable[str]] = None
) -> list[Finding]:
    checks = select_checks(select)
    findings: list[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(analyze_file(f, checks=checks))
    return findings
