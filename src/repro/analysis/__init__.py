"""Static-analysis suite for the repro JAX/Pallas codebase.

Four check families guard the invariants the paper's performance claims
rest on (see docs/static_analysis.md):

  PK*  Pallas kernel structure: grid/BlockSpec arity, (8, 128) tile
       alignment, kernel ref arity, static VMEM budgets, out-spec counts.
  JH*  jit hygiene: static_argnames/donate_argnums vs signature, jit
       constructed per call, unhashable statics, host calls in traces.
  DT*  dtype discipline: float64 leaks, MXU accumulation dtype.
  OB*  observability discipline: bare print() in library code (route
       through repro.obs instead; CLIs and benchmarks are exempt).

Programmatic API::

    from repro.analysis import analyze_paths, analyze_source
    findings = analyze_paths(["src"])        # list[Finding]

CLI::

    python -m repro.analysis src/ --baseline analysis-baseline.json
"""
from repro.analysis.core import (  # noqa: F401
    Check,
    Finding,
    ModuleContext,
    all_checks,
    analyze_file,
    analyze_paths,
    analyze_source,
    register,
    select_checks,
)
