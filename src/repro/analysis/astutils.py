"""AST helpers shared by the static-analysis checks.

The checks never execute repo code — every question ("what is this block
shape?", "which function does this ``pl.pallas_call`` run?") is answered by
constant-folding the AST against a small environment: module-level constant
assignments, function keyword defaults, and simple straight-line local
assignments. Anything unresolvable folds to ``None`` and the checks treat it
as unknown rather than guessing.
"""
from __future__ import annotations

import ast
from typing import Any, Optional


# ---------------------------------------------------------------------------
# name resolution
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.experimental.pallas.pallas_call`` -> the dotted string, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def get_kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def has_kwarg(call: ast.Call, name: str) -> bool:
    return get_kwarg(call, name) is not None


# ---------------------------------------------------------------------------
# constant folding
# ---------------------------------------------------------------------------


def module_const_env(tree: ast.Module) -> dict[str, Any]:
    """Collect module-level ``NAME = <int/float/str literal>`` assignments."""
    env: dict[str, Any] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                val = fold_const(node.value, {})
                if val is not None:
                    env[tgt.id] = val
    return env


def function_env(
    fn: ast.FunctionDef, base: dict[str, Any]
) -> dict[str, Any]:
    """base env + keyword defaults + straight-line local constant assigns.

    This resolves the idiomatic kernel-wrapper pattern::

        def wrapper(x, *, block_s: int = 256):
            bs = min(block_s, s)          # folds to <= 256

    Locals are folded in source order, one forward pass — loops and branches
    are not interpreted (their targets become unresolvable, which is the
    conservative outcome).
    """
    env = dict(base)
    args = fn.args
    # positional defaults align to the tail of args.args
    pos = list(args.posonlyargs) + list(args.args)
    for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        val = fold_const(d, env)
        if val is not None:
            env[a.arg] = val
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            val = fold_const(d, env)
            if val is not None:
                env[a.arg] = val
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if isinstance(tgt, ast.Name):
            val = fold_const(node.value, env)
            if val is not None:
                env.setdefault(tgt.id, val)
        elif (
            # tuple unpacking of constants: bs, bk = 8, 128
            isinstance(tgt, ast.Tuple)
            and isinstance(node.value, ast.Tuple)
            and len(tgt.elts) == len(node.value.elts)
        ):
            for t, v in zip(tgt.elts, node.value.elts):
                if isinstance(t, ast.Name):
                    val = fold_const(v, env)
                    if val is not None:
                        env.setdefault(t.id, val)
    return env


def fold_const(node: ast.AST, env: dict[str, Any]) -> Optional[Any]:
    """Best-effort constant fold of an expression to int/float/str.

    ``min``/``max`` calls fold over their *resolvable* arguments — for block
    shapes this yields a sound upper bound, because ``min(block, dim)`` can
    only shrink below the resolvable operand.
    """
    if isinstance(node, ast.Constant):
        if isinstance(node.value, (int, float, str)) and not isinstance(
            node.value, bool
        ):
            return node.value
        return None
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = fold_const(node.operand, env)
        return -v if isinstance(v, (int, float)) else None
    if isinstance(node, ast.BinOp):
        l = fold_const(node.left, env)
        r = fold_const(node.right, env)
        if isinstance(l, (int, float)) and isinstance(r, (int, float)):
            try:
                if isinstance(node.op, ast.Add):
                    return l + r
                if isinstance(node.op, ast.Sub):
                    return l - r
                if isinstance(node.op, ast.Mult):
                    return l * r
                if isinstance(node.op, ast.FloorDiv):
                    return l // r
                if isinstance(node.op, ast.Mod):
                    return l % r
                if isinstance(node.op, ast.Pow):
                    return l ** r
                if isinstance(node.op, ast.LShift):
                    return l << r
            except (ZeroDivisionError, TypeError, ValueError):
                return None
        return None
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in ("min", "max") and not node.keywords:
            vals = [fold_const(a, env) for a in node.args]
            nums = [v for v in vals if isinstance(v, (int, float))]
            if not nums:
                return None
            if name == "min":
                # sound upper bound even when some args are unknown
                return min(nums)
            # max over partial args is NOT an upper bound: only fold when
            # every argument resolved
            if len(nums) == len(vals):
                return max(nums)
        return None
    return None


def fold_shape(
    node: Optional[ast.AST], env: dict[str, Any]
) -> Optional[tuple[Optional[int], ...]]:
    """Fold a shape tuple/list; unresolvable dims become None."""
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    dims = []
    for e in node.elts:
        v = fold_const(e, env)
        dims.append(v if isinstance(v, int) else None)
    return tuple(dims)


# dtype attribute suffix -> itemsize in bytes
_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def dtype_bytes(node: Optional[ast.AST], default: int = 4) -> int:
    """Itemsize of a dtype expression like ``jnp.float32`` (default f32)."""
    if node is None:
        return default
    name = dotted_name(node)
    if name is None and isinstance(node, ast.Constant) and isinstance(node.value, str):
        name = node.value
    if name is None:
        return default
    return _DTYPE_BYTES.get(name.rsplit(".", 1)[-1], default)


def dtype_name(node: Optional[ast.AST]) -> Optional[str]:
    if node is None:
        return None
    name = dotted_name(node)
    if name is None and isinstance(node, ast.Constant) and isinstance(node.value, str):
        name = node.value
    return name.rsplit(".", 1)[-1] if name else None


# ---------------------------------------------------------------------------
# structural helpers
# ---------------------------------------------------------------------------


def function_defs(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    """All function defs in the module, including methods (qualified access
    is by bare name — collisions keep the first definition)."""
    out: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


def positional_params(fn: ast.FunctionDef) -> list[str]:
    return [a.arg for a in list(fn.args.posonlyargs) + list(fn.args.args)]


def all_params(fn: ast.FunctionDef) -> list[str]:
    names = positional_params(fn)
    names += [a.arg for a in fn.args.kwonlyargs]
    if fn.args.vararg:
        names.append(fn.args.vararg.arg)
    if fn.args.kwarg:
        names.append(fn.args.kwarg.arg)
    return names


def param_default(fn: ast.FunctionDef, name: str) -> Optional[ast.expr]:
    """Default-value expression of parameter ``name``, if any."""
    pos = list(fn.args.posonlyargs) + list(fn.args.args)
    off = len(pos) - len(fn.args.defaults)
    for i, a in enumerate(pos):
        if a.arg == name and i >= off:
            return fn.args.defaults[i - off]
    for a, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if a.arg == name and d is not None:
            return d
    return None


def lambda_arity(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Lambda):
        a = node.args
        return len(a.posonlyargs) + len(a.args)
    return None


def resolve_callable(
    node: ast.AST, defs: dict[str, ast.FunctionDef]
) -> tuple[Optional[ast.FunctionDef], list[str]]:
    """Resolve a callable expression to a module FunctionDef.

    Handles ``kernel_fn``, ``functools.partial(kernel_fn, a=1)``, and nested
    partials. Returns (def-or-None, keyword names bound by partials).
    """
    bound: list[str] = []
    while isinstance(node, ast.Call) and call_name(node) in (
        "functools.partial", "partial",
    ):
        bound += [kw.arg for kw in node.keywords if kw.arg]
        if not node.args:
            return None, bound
        node = node.args[0]
    if isinstance(node, ast.Name):
        return defs.get(node.id), bound
    name = dotted_name(node)
    if name and "." in name:
        return defs.get(name.rsplit(".", 1)[-1]), bound
    return None, bound


def elements(node: Optional[ast.AST]) -> Optional[list[ast.expr]]:
    """Elements of a list/tuple literal, else None (single value -> [value])."""
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]  # single spec / shape allowed by pallas_call


def parent_map(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def enclosing_function(
    node: ast.AST, parents: dict[ast.AST, ast.AST]
) -> Optional[ast.FunctionDef]:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None


def decorator_nodes(tree: ast.AST) -> set[ast.AST]:
    """Every AST node that lives inside some decorator expression."""
    out: set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            for dec in node.decorator_list:
                out.update(ast.walk(dec))
    return out
