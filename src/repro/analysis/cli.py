"""``python -m repro.analysis`` — run the static-analysis suite.

Exit codes: 0 clean (modulo baseline), 1 new findings, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import baseline as baseline_mod
from repro.analysis import core


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis for the repro JAX/Pallas codebase: "
        "Pallas kernel invariants (PK), jit hygiene (JH), dtype "
        "discipline (DT), observability discipline (OB).",
    )
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to analyze (default: src)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as JSON on stdout")
    p.add_argument("--baseline", metavar="FILE",
                   help="baseline file; findings fingerprinted there are "
                   "reported as grandfathered and do not fail the run")
    p.add_argument("--write-baseline", metavar="FILE",
                   help="write all current findings to FILE and exit 0")
    p.add_argument("--select", metavar="CODES",
                   help="comma-separated codes or prefixes, e.g. PK002,JH")
    p.add_argument("--vmem-limit-mib", type=int, default=None, metavar="N",
                   help="override the PK004 VMEM budget (default 16)")
    p.add_argument("--list-checks", action="store_true",
                   help="list registered checks and exit")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress grandfathered findings in human output")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_checks:
        for c in core.all_checks():
            print(f"{c.code}  {c.name}\n    {c.description}")
        return 0

    if args.vmem_limit_mib is not None:
        from repro.analysis import checks_pallas

        checks_pallas.VMEM_LIMIT_BYTES = args.vmem_limit_mib * 1024 * 1024

    missing = [p for p in args.paths if not os.path.exists(p)]
    if missing:
        print(f"error: no such path(s): {', '.join(missing)}", file=sys.stderr)
        return 2

    select = args.select.split(",") if args.select else None
    try:
        findings = core.analyze_paths(args.paths, select=select)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        n = baseline_mod.write(args.write_baseline, findings)
        print(f"wrote {n} fingerprint(s) to {args.write_baseline}")
        return 0

    base: set[str] = set()
    if args.baseline:
        try:
            base = baseline_mod.load(args.baseline)
        except (OSError, ValueError, KeyError) as e:
            print(f"error: cannot load baseline: {e}", file=sys.stderr)
            return 2
    new, old = baseline_mod.split(findings, base)
    stale = base - {f.fingerprint for f in findings}

    if args.as_json:
        json.dump(
            {
                "new": [f.to_json() for f in new],
                "grandfathered": [f.to_json() for f in old],
                "stale_baseline_entries": sorted(stale),
                "summary": {"new": len(new), "grandfathered": len(old),
                            "stale": len(stale)},
            },
            sys.stdout, indent=2,
        )
        print()
        return 1 if new else 0

    for f in new:
        print(f"{f.path}:{f.line}:{f.col + 1}: {f.code} {f.message}")
    if old and not args.quiet:
        for f in old:
            print(
                f"{f.path}:{f.line}:{f.col + 1}: {f.code} [baseline] "
                f"{f.message}"
            )
    if stale and not args.quiet:
        print(f"note: {len(stale)} stale baseline entr(y/ies) — "
              f"refresh with --write-baseline")
    print(
        f"{len(new)} new finding(s), {len(old)} grandfathered"
        + (f", {len(stale)} stale baseline" if stale else "")
    )
    return 1 if new else 0
