"""Baseline files: grandfather existing findings so CI gates only on NEW ones.

The baseline is a JSON document of finding *fingerprints*
(``path::CODE::stripped-source-line``) — line numbers are deliberately
excluded so unrelated edits that shift a file do not resurrect grandfathered
findings. Fixing the flagged line (or moving the file) invalidates the
fingerprint, at which point the entry is stale and ``--write-baseline``
prunes it.
"""
from __future__ import annotations

import json
import os
from typing import Iterable

from repro.analysis.core import Finding

BASELINE_VERSION = 1


def load(path: str) -> set[str]:
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    if isinstance(doc, list):  # bare fingerprint list is accepted
        return set(doc)
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported baseline version {doc.get('version')!r} in {path}"
        )
    return set(doc["fingerprints"])


def write(path: str, findings: Iterable[Finding]) -> int:
    fps = sorted({f.fingerprint for f in findings})
    doc = {"version": BASELINE_VERSION, "fingerprints": fps}
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    os.replace(tmp, path)
    return len(fps)


def split(
    findings: Iterable[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding]]:
    """Partition into (new, grandfathered)."""
    new, old = [], []
    for f in findings:
        (old if f.fingerprint in baseline else new).append(f)
    return new, old
