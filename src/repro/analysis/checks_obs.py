"""OB: observability discipline — no bare ``print`` in library code.

Library modules under ``src/repro/`` must report through ``repro.obs``
(spans, events, metrics) or raise — a bare ``print`` bypasses the trace,
interleaves arbitrarily across threads, and is invisible to the JSONL
summarizer. CLI entry points are where human-readable output belongs, so
launch drivers, ``cli.py``/``__main__.py`` modules and ``benchmarks/`` are
exempt.

Codes:
  OB001  bare print() call in library code (use repro.obs or logging)
"""
from __future__ import annotations

import ast

from repro.analysis import astutils as au
from repro.analysis.core import ModuleContext, register

# Path parts / basenames where print IS the product (human-facing CLIs).
_EXEMPT_PARTS = ("launch", "benchmarks")
_EXEMPT_BASENAMES = ("cli.py", "__main__.py")


def _is_exempt(path: str) -> bool:
    parts = path.replace("\\", "/").split("/")
    if parts and parts[-1] in _EXEMPT_BASENAMES:
        return True
    return any(p in _EXEMPT_PARTS for p in parts)


@register(
    "OB001",
    "print-in-library",
    "Bare print() in library code bypasses repro.obs tracing and interleaves "
    "across threads — emit an obs event/metric or raise instead (launch "
    "CLIs, cli.py/__main__.py and benchmarks/ are exempt).",
)
def check_print_in_library(ctx: ModuleContext):
    if _is_exempt(ctx.path):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if au.call_name(node) != "print":
            continue
        # A shadowed local `print = ...` binding is not the builtin; keep the
        # check simple and only skip the common kwargs-free stderr idiom:
        # print(..., file=sys.stderr) is deliberate diagnostics.
        file_kw = next((kw for kw in node.keywords if kw.arg == "file"), None)
        if file_kw is not None:
            target = au.dotted_name(file_kw.value) if isinstance(
                file_kw.value, (ast.Attribute, ast.Name)) else None
            if target in ("sys.stderr", "stderr"):
                continue
        yield ctx.finding(
            "OB001", node,
            "bare print() in library code — route through repro.obs "
            "(event/inc/span) so it lands in the trace, or write to "
            "sys.stderr if it is a deliberate diagnostic",
        )
