"""PK: Pallas kernel checks — pallas_call structure and VMEM budgets.

Every check walks the AST only; shapes are constant-folded against module
constants and wrapper-function keyword defaults (``block_s: int = 256``), so
``min(block_s, s)`` folds to a sound upper bound of 256 even though ``s`` is
data-dependent. Dims that do not fold are treated as unknown and never
flagged — the checks under-report rather than guess.

Codes:
  PK001  grid arity != BlockSpec index_map arity
  PK002  block shape not (8, 128)-aligned (dims of 1 are exempt)
  PK003  kernel positional-parameter count != in_specs+out_specs+scratch
  PK004  static VMEM estimate (2x in/out blocks + scratch) exceeds budget
  PK005  out_specs and out_shape lengths disagree
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, Optional

from repro.analysis import astutils as au
from repro.analysis.core import Finding, ModuleContext, register

# TPU VMEM is ~16 MiB/core; leave headroom for the compiler's own use.
VMEM_LIMIT_BYTES = 16 * 1024 * 1024
SUBLANE, LANE = 8, 128

_PALLAS_CALL_NAMES = ("pl.pallas_call", "pallas_call", "pltpu.pallas_call")
_BLOCKSPEC_NAMES = ("pl.BlockSpec", "BlockSpec", "pltpu.PrefetchScalarGridSpec")
_SCRATCH_VMEM = ("pltpu.VMEM", "VMEM")
_SCRATCH_ANY = _SCRATCH_VMEM + ("pltpu.SMEM", "SMEM", "pltpu.SemaphoreType.DMA")


@dataclasses.dataclass
class PallasCallSite:
    call: ast.Call
    env: dict                      # folding environment at the call site
    grid: Optional[list[ast.expr]]
    in_specs: Optional[list[ast.expr]]
    out_specs: Optional[list[ast.expr]]
    out_shape: Optional[list[ast.expr]]
    scratch_shapes: Optional[list[ast.expr]]


def pallas_call_sites(ctx: ModuleContext) -> Iterator[PallasCallSite]:
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and au.call_name(node) in _PALLAS_CALL_NAMES):
            continue
        fn = au.enclosing_function(node, ctx.parents)
        env = au.function_env(fn, ctx.const_env) if fn else dict(ctx.const_env)
        grid = au.get_kwarg(node, "grid")
        grid_elts = None
        if isinstance(grid, (ast.Tuple, ast.List)):
            grid_elts = list(grid.elts)
        elif grid is not None:
            grid_elts = [grid]  # grid=n means a 1-d grid
        yield PallasCallSite(
            call=node,
            env=env,
            grid=grid_elts,
            in_specs=au.elements(au.get_kwarg(node, "in_specs")),
            out_specs=au.elements(au.get_kwarg(node, "out_specs")),
            out_shape=au.elements(au.get_kwarg(node, "out_shape")),
            scratch_shapes=au.elements(au.get_kwarg(node, "scratch_shapes")),
        )


def kernel_def_for(
    site: PallasCallSite, ctx: ModuleContext
) -> tuple[Optional[ast.FunctionDef], list[str]]:
    """Resolve the kernel body this pallas_call runs (through partials)."""
    if not site.call.args:
        return None, []
    return au.resolve_callable(site.call.args[0], ctx.defs)


def _block_specs(site: PallasCallSite) -> Iterator[tuple[str, ast.Call]]:
    for role, specs in (("in_specs", site.in_specs), ("out_specs", site.out_specs)):
        for spec in specs or []:
            if isinstance(spec, ast.Call) and au.call_name(spec) in _BLOCKSPEC_NAMES:
                yield role, spec


def _spec_shape_node(spec: ast.Call) -> Optional[ast.expr]:
    if spec.args:
        return spec.args[0]
    return au.get_kwarg(spec, "block_shape")


def _spec_index_map(spec: ast.Call) -> Optional[ast.expr]:
    if len(spec.args) >= 2:
        return spec.args[1]
    return au.get_kwarg(spec, "index_map")


@register(
    "PK001",
    "grid-index-map-arity",
    "Every BlockSpec index_map must take exactly one argument per grid axis.",
)
def check_grid_arity(ctx: ModuleContext):
    for site in pallas_call_sites(ctx):
        if site.grid is None:
            continue
        n_grid = len(site.grid)
        for role, spec in _block_specs(site):
            imap = _spec_index_map(spec)
            arity = au.lambda_arity(imap) if imap is not None else None
            if arity is not None and arity != n_grid:
                yield ctx.finding(
                    "PK001",
                    spec,
                    f"{role} BlockSpec index_map takes {arity} arg(s) but the "
                    f"grid has {n_grid} axis/axes — Pallas passes one program "
                    f"id per grid axis",
                )


@register(
    "PK002",
    "tile-alignment",
    "Block shapes must be multiples of (8, 128) on the last two axes "
    "(dims of exactly 1 are exempt).",
)
def check_tile_alignment(ctx: ModuleContext):
    for site in pallas_call_sites(ctx):
        for role, spec in _block_specs(site):
            shape_node = _spec_shape_node(spec)
            shape = au.fold_shape(shape_node, site.env)
            if not shape:
                continue
            checks = []
            if len(shape) >= 2:
                checks = [(shape[-2], SUBLANE, "second-to-last"),
                          (shape[-1], LANE, "last")]
            elif len(shape) == 1:
                checks = [(shape[-1], LANE, "last")]
            for dim, mult, which in checks:
                if dim is not None and dim > 1 and dim % mult != 0:
                    yield ctx.finding(
                        "PK002",
                        shape_node or spec,
                        f"{role} block shape {shape} has {which} dim {dim}, "
                        f"not a multiple of {mult} — the tile will be "
                        f"silently padded or rejected by Mosaic",
                    )


@register(
    "PK003",
    "kernel-ref-arity",
    "The kernel body must take one positional ref per input, output and "
    "scratch buffer, in that order.",
)
def check_kernel_arity(ctx: ModuleContext):
    for site in pallas_call_sites(ctx):
        kdef, bound = kernel_def_for(site, ctx)
        if kdef is None or site.in_specs is None:
            continue
        n_out = None
        if site.out_specs is not None:
            n_out = len(site.out_specs)
        elif site.out_shape is not None:
            n_out = len(site.out_shape)
        if n_out is None:
            continue
        n_scratch = len(site.scratch_shapes or [])
        expected = len(site.in_specs) + n_out + n_scratch
        pos = au.positional_params(kdef)
        # partial() may bind positional params by keyword
        got = len([p for p in pos if p not in bound])
        if got != expected:
            yield ctx.finding(
                "PK003",
                site.call,
                f"kernel `{kdef.name}` takes {got} positional ref(s) but "
                f"pallas_call supplies {expected} "
                f"({len(site.in_specs)} in + {n_out} out + {n_scratch} scratch)",
            )


@register(
    "PK004",
    "vmem-budget",
    "Static VMEM estimate (2x double-buffered in/out blocks + scratch) must "
    "stay under the ~16 MiB/core budget.",
)
def check_vmem_budget(ctx: ModuleContext):
    for site in pallas_call_sites(ctx):
        total = 0
        parts = []
        # out_shape dtypes line up with out_specs by position
        out_dtypes: list[Optional[ast.expr]] = []
        for sd in site.out_shape or []:
            if isinstance(sd, ast.Call):
                out_dtypes.append(
                    sd.args[1] if len(sd.args) >= 2 else au.get_kwarg(sd, "dtype")
                )
            else:
                out_dtypes.append(None)
        for role, specs in (("in", site.in_specs), ("out", site.out_specs)):
            for i, spec in enumerate(specs or []):
                if not (
                    isinstance(spec, ast.Call)
                    and au.call_name(spec) in _BLOCKSPEC_NAMES
                ):
                    continue
                shape = au.fold_shape(_spec_shape_node(spec), site.env)
                if not shape or any(d is None for d in shape):
                    continue  # unknown dim: cannot bound this buffer
                itemsize = 4
                if role == "out" and i < len(out_dtypes):
                    itemsize = au.dtype_bytes(out_dtypes[i])
                nbytes = _prod(shape) * itemsize * 2  # 2x: pipeline buffers
                total += nbytes
                parts.append(f"{role}{i}:{_fmt_mib(nbytes)}")
        for i, sc in enumerate(site.scratch_shapes or []):
            if not (isinstance(sc, ast.Call) and au.call_name(sc) in _SCRATCH_VMEM):
                continue
            shape = au.fold_shape(
                sc.args[0] if sc.args else au.get_kwarg(sc, "shape"), site.env
            )
            if not shape or any(d is None for d in shape):
                continue
            dt = sc.args[1] if len(sc.args) >= 2 else au.get_kwarg(sc, "dtype")
            nbytes = _prod(shape) * au.dtype_bytes(dt)
            total += nbytes
            parts.append(f"scratch{i}:{_fmt_mib(nbytes)}")
        if total > VMEM_LIMIT_BYTES:
            yield ctx.finding(
                "PK004",
                site.call,
                f"estimated VMEM footprint {_fmt_mib(total)} exceeds the "
                f"{_fmt_mib(VMEM_LIMIT_BYTES)} budget "
                f"({', '.join(parts)}) — shrink block sizes or spill "
                f"accumulators",
            )


@register(
    "PK005",
    "out-spec-shape-count",
    "out_specs and out_shape must describe the same number of outputs.",
)
def check_out_counts(ctx: ModuleContext):
    for site in pallas_call_sites(ctx):
        if site.out_specs is None or site.out_shape is None:
            continue
        if len(site.out_specs) != len(site.out_shape):
            yield ctx.finding(
                "PK005",
                site.call,
                f"pallas_call declares {len(site.out_specs)} out_specs but "
                f"{len(site.out_shape)} out_shape entries",
            )


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _fmt_mib(nbytes: int) -> str:
    return f"{nbytes / (1024 * 1024):.2f}MiB"
