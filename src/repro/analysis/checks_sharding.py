"""SH: sharding-spec hygiene for NamedSharding / shard_map call sites.

A ``PartitionSpec`` names mesh axes by string; nothing in jax checks the
names until the array (or the shard_map trace) actually touches the mesh,
and on some paths a typo silently replicates instead of sharding — the
program runs, just 16x slower and with a device-memory footprint that only
blows up at scale. The check cross-references every axis-name literal in a
spec against the axis names of the mesh the same call site consumes.

Resolution is deliberately conservative (astutils philosophy: never guess):
the mesh must resolve — directly or through one local assignment — to a
``jax.make_mesh(shape, axis_names)`` / ``Mesh(devices, axis_names)`` call
with a *literal* tuple of axis names, and only string literals inside
``P(...)`` / ``PartitionSpec(...)`` are checked. Meshes built by helper
functions (``make_host_mesh()``) or passed as parameters are unknown and
skipped.

Codes:
  SH001  PartitionSpec axis name absent from the consuming mesh
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis import astutils as au
from repro.analysis.core import ModuleContext, register

_MESH_CTORS = ("make_mesh", "Mesh", "AbstractMesh")
_SPEC_CTORS = ("P", "PartitionSpec")


def _literal_axis_names(node: ast.AST) -> Optional[tuple[str, ...]]:
    """('data', 'model') / ['data'] / 'data' -> axis-name tuple, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        names = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.append(e.value)
            else:
                return None
        return tuple(names)
    return None


def _mesh_axes_from_call(call: ast.Call) -> Optional[tuple[str, ...]]:
    """Axis names of a literal mesh constructor call, else None."""
    name = au.call_name(call)
    if name is None or name.split(".")[-1] not in _MESH_CTORS:
        return None
    arg = au.get_kwarg(call, "axis_names")
    if arg is None and len(call.args) >= 2:
        arg = call.args[1]
    return _literal_axis_names(arg) if arg is not None else None


def _assignment_env(tree: ast.Module) -> dict[str, ast.expr]:
    """name -> value node for every single-target assignment (last wins)."""
    env: dict[str, ast.expr] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                env[tgt.id] = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if isinstance(node.target, ast.Name):
                env[node.target.id] = node.value
    return env


def _resolve(node: ast.AST, env: dict[str, ast.expr]) -> ast.AST:
    """Follow one Name -> assignment hop (no recursion: stays conservative)."""
    if isinstance(node, ast.Name) and node.id in env:
        return env[node.id]
    return node


def _resolve_mesh_axes(
    node: ast.AST, env: dict[str, ast.expr]
) -> Optional[tuple[str, ...]]:
    node = _resolve(node, env)
    if isinstance(node, ast.Call):
        return _mesh_axes_from_call(node)
    return None


def _spec_axis_literals(node: ast.AST, env: dict[str, ast.expr]):
    """Yield (axis-name, anchor-node) for every string literal inside a
    P(...)/PartitionSpec(...) call reachable from ``node``."""
    node = _resolve(node, env)
    for sub in ast.walk(node if isinstance(node, ast.AST) else ast.Module()):
        if not isinstance(sub, ast.Call):
            continue
        name = au.call_name(sub)
        if name is None or name.split(".")[-1] not in _SPEC_CTORS:
            continue
        for arg in list(sub.args) + [kw.value for kw in sub.keywords]:
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                yield arg.value, arg
            elif isinstance(arg, (ast.Tuple, ast.List)):
                for e in arg.elts:
                    if isinstance(e, ast.Constant) and isinstance(
                            e.value, str):
                        yield e.value, e


@register(
    "SH001",
    "spec-axis-not-in-mesh",
    "PartitionSpec axis names must exist in the mesh consumed by the same "
    "NamedSharding/shard_map call site — a typo silently replicates the "
    "array instead of sharding it.",
)
def check_spec_axes_exist(ctx: ModuleContext):
    env = _assignment_env(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = au.call_name(node)
        base = name.split(".")[-1] if name else None
        if base == "NamedSharding":
            mesh_arg = au.get_kwarg(node, "mesh")
            if mesh_arg is None and node.args:
                mesh_arg = node.args[0]
            spec_args = []
            spec_kw = au.get_kwarg(node, "spec")
            if spec_kw is not None:
                spec_args.append(spec_kw)
            elif len(node.args) >= 2:
                spec_args.append(node.args[1])
        elif base == "shard_map":
            mesh_arg = au.get_kwarg(node, "mesh")
            if mesh_arg is None and len(node.args) >= 2:
                mesh_arg = node.args[1]
            spec_args = [
                a for a in (
                    au.get_kwarg(node, "in_specs"),
                    au.get_kwarg(node, "out_specs"),
                ) if a is not None
            ]
        else:
            continue
        if mesh_arg is None or not spec_args:
            continue
        axes = _resolve_mesh_axes(mesh_arg, env)
        if axes is None:
            continue  # mesh not statically resolvable — never guess
        for spec_arg in spec_args:
            for axis, anchor in _spec_axis_literals(spec_arg, env):
                if axis not in axes:
                    yield ctx.finding(
                        "SH001", anchor,
                        f"PartitionSpec names axis {axis!r} but the "
                        f"consuming mesh only has axes {tuple(axes)!r}",
                    )
