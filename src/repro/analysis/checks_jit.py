"""JH: jax.jit hygiene — fast-path invariants for the strategy loops.

The paper's speedups assume every hot entry point compiles once and then
replays; all four hazards below silently re-trace or re-compile instead.

Codes:
  JH001  static_argnames entry not in the wrapped function's signature
  JH002  donate_argnums index out of range of the positional parameters
  JH003  jax.jit constructed inside a function/method body (a fresh jit
         wrapper per call defeats the compile cache across calls/instances)
  JH004  static parameter whose default is an unhashable literal
  JH005  host-side numpy / Python-RNG call inside a jitted function body
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis import astutils as au
from repro.analysis.core import ModuleContext, register

_JIT_NAMES = ("jax.jit", "jit", "api.jit")
_PARTIAL_NAMES = ("functools.partial", "partial")
# Memoized factories are the sanctioned alternative JH003's message points
# at: the jit is constructed once per distinct key, not once per call.
_CACHE_DECORATORS = (
    "functools.lru_cache", "lru_cache", "functools.cache", "cache",
)

# numpy attribute accesses that are legal inside a trace (dtypes, constants —
# not data-producing calls).
_NP_CALL_ALLOWED = {
    "float32", "float64", "float16", "bfloat16",
    "int8", "int16", "int32", "int64", "uint8", "uint32", "uint64",
    "bool_", "dtype", "shape", "ndim",
}
_HOST_RNG_PREFIXES = ("np.random.", "numpy.random.", "random.")


def _is_jit_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and au.call_name(node) in _JIT_NAMES


def _is_cached_factory(fn: ast.FunctionDef) -> bool:
    """True when ``fn`` is decorated with lru_cache/cache (any idiom:
    ``@lru_cache``, ``@functools.lru_cache(maxsize=None)``)."""
    for dec in fn.decorator_list:
        name = au.dotted_name(dec)
        if name is None and isinstance(dec, ast.Call):
            name = au.call_name(dec)
        if name in _CACHE_DECORATORS:
            return True
    return False


def _jit_targets(
    ctx: ModuleContext,
) -> Iterator[tuple[Optional[ast.Call], Optional[ast.FunctionDef]]]:
    """All jit applications with the function they wrap (when resolvable).

    Three idioms are recognized::

        jax.jit(fn, static_argnames=...)            # call form
        @functools.partial(jax.jit, static_...)     # partial-decorator form
        @jax.jit                                    # bare decorator (call=None)
    """
    seen: set[ast.AST] = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            if _is_jit_call(dec):
                yield dec, node
                seen.add(dec)
            elif au.dotted_name(dec) in _JIT_NAMES:
                yield None, node
            elif (
                isinstance(dec, ast.Call)
                and au.call_name(dec) in _PARTIAL_NAMES
                and dec.args
                and au.dotted_name(dec.args[0]) in _JIT_NAMES
            ):
                yield dec, node
                seen.add(dec)
    for node in ast.walk(ctx.tree):
        if _is_jit_call(node) and node not in seen:
            fdef = None
            if node.args:
                fdef, _ = au.resolve_callable(node.args[0], ctx.defs)
            yield node, fdef


def _static_argnames(call: ast.Call) -> tuple[Optional[ast.expr], list[str]]:
    node = au.get_kwarg(call, "static_argnames")
    if node is None:
        return None, []
    names: list[str] = []
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        names = [node.value]
    elif isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.append(e.value)
    return node, names


@register(
    "JH001",
    "static-argnames-signature",
    "Every static_argnames entry must name a parameter of the wrapped "
    "function; unknown names raise only at first call (or never, under "
    "**kwargs).",
)
def check_static_argnames(ctx: ModuleContext):
    for call, fdef in _jit_targets(ctx):
        if fdef is None or call is None:
            continue
        node, names = _static_argnames(call)
        if node is None:
            continue
        params = set(au.all_params(fdef))
        if fdef.args.kwarg is not None:
            continue  # **kwargs swallows anything — cannot validate
        for n in names:
            if n not in params:
                yield ctx.finding(
                    "JH001",
                    node,
                    f"static_argnames entry {n!r} is not a parameter of "
                    f"`{fdef.name}` ({', '.join(au.all_params(fdef)) or 'no params'})",
                )


@register(
    "JH002",
    "donate-argnums-range",
    "donate_argnums indices must address positional parameters of the "
    "wrapped function.",
)
def check_donate_argnums(ctx: ModuleContext):
    for call, fdef in _jit_targets(ctx):
        if fdef is None or call is None:
            continue
        node = au.get_kwarg(call, "donate_argnums")
        if node is None:
            continue
        idxs: list[int] = []
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            idxs = [node.value]
        elif isinstance(node, (ast.Tuple, ast.List)):
            idxs = [
                e.value
                for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)
            ]
        if fdef.args.vararg is not None:
            continue  # *args accepts any index
        n_pos = len(au.positional_params(fdef))
        for i in idxs:
            if i < 0 or i >= n_pos:
                yield ctx.finding(
                    "JH002",
                    node,
                    f"donate_argnums index {i} is out of range for "
                    f"`{fdef.name}` which has {n_pos} positional parameter(s)",
                )


@register(
    "JH003",
    "jit-in-function-body",
    "jax.jit constructed inside a function/method body creates a fresh "
    "compile cache per call — hoist it to module level or cache it.",
)
def check_jit_in_body(ctx: ModuleContext):
    for node in ast.walk(ctx.tree):
        if not _is_jit_call(node) or node in ctx.decorator_nodes:
            continue
        fn = au.enclosing_function(node, ctx.parents)
        if fn is None:
            continue
        # Exempt memoized factories (the fix this check recommends): a jit
        # built inside an lru_cache'd function — at any nesting depth — is
        # constructed once per cache key.
        enclosing, cached = fn, False
        while enclosing is not None:
            if _is_cached_factory(enclosing):
                cached = True
                break
            enclosing = au.enclosing_function(enclosing, ctx.parents)
        if cached:
            continue
        yield ctx.finding(
            "JH003",
            node,
            f"jax.jit is constructed inside `{fn.name}` — every call "
            f"re-wraps and re-traces; hoist the jitted callable to module "
            f"level (or functools.lru_cache it) so the compile cache is "
            f"shared across calls",
        )


@register(
    "JH004",
    "unhashable-static-default",
    "Parameters marked static must be hashable; list/dict/set defaults "
    "raise at trace time.",
)
def check_unhashable_static(ctx: ModuleContext):
    for call, fdef in _jit_targets(ctx):
        if fdef is None or call is None:
            continue
        _, names = _static_argnames(call)
        for n in names:
            default = au.param_default(fdef, n)
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                kind = type(default).__name__.lower()
                yield ctx.finding(
                    "JH004",
                    default,
                    f"static parameter {n!r} of `{fdef.name}` defaults to an "
                    f"unhashable {kind} literal — jit hashes static args, so "
                    f"the default value raises TypeError; use a tuple or "
                    f"frozen container",
                )


@register(
    "JH005",
    "host-call-in-jit",
    "numpy / Python-RNG calls inside a jitted body run at trace time on the "
    "host — they bake constants into the graph or crash on tracers.",
)
def check_host_calls(ctx: ModuleContext):
    jitted: dict[ast.FunctionDef, bool] = {}
    for _, fdef in _jit_targets(ctx):
        if fdef is not None:
            jitted[fdef] = True
    for fdef in jitted:
        for node in ast.walk(fdef):
            if not isinstance(node, ast.Call):
                continue
            name = au.call_name(node)
            if name is None:
                continue
            if any(name.startswith(p) for p in _HOST_RNG_PREFIXES):
                yield ctx.finding(
                    "JH005",
                    node,
                    f"`{name}` inside jitted `{fdef.name}` draws host "
                    f"randomness at trace time — the value freezes into the "
                    f"compiled graph; use jax.random with an explicit key",
                )
            elif name.startswith(("np.", "numpy.")):
                attr = name.split(".", 1)[1]
                if attr.split(".")[0] in _NP_CALL_ALLOWED:
                    continue
                yield ctx.finding(
                    "JH005",
                    node,
                    f"host-side `{name}` inside jitted `{fdef.name}` — numpy "
                    f"executes at trace time and fails on tracers; use "
                    f"jax.numpy",
                )
