"""Fault-tolerant training runner.

What "runs on thousands of nodes" actually requires, demonstrated at CPU
scale and tested in tests/test_runtime.py:

  * periodic atomic checkpoints (CheckpointManager) with async save;
  * crash -> restart-from-latest: Trainer.run() survives injected step
    failures (``failure_at``) by reloading the newest checkpoint and
    continuing — the same path a preempted TPU worker takes on reschedule;
  * preemption hook: SIGTERM sets a flag; the loop checkpoints and exits
    cleanly at the next step boundary;
  * elastic restart: checkpoints are host-gathered and mesh-agnostic, so a
    restart may use a different device count (see tests);
  * metrics JSONL for post-hoc analysis.

Straggler note (clustering workloads): HPClust's keep-the-best coordination
is intrinsically straggler-tolerant — a slow worker can only fail to
*contribute*, never block the incumbent (cooperative rounds take a pmin of
whatever every group has *now*). The trainer-level analogue here is the
checkpoint/restart path plus bounded step deadlines.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Iterator

import jax

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.resilience.preemption import PreemptionGuard


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    async_save: bool = False
    max_restarts: int = 3
    log_path: str | None = None


class StepFailure(RuntimeError):
    """Injected (or surfaced) step-level failure."""


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        step_fn: Callable,        # (params, opt_state, batch) -> (p, o, metrics)
        init_state: Callable[[], tuple[Any, Any]],
        data: Iterator[dict],
        *,
        failure_at: set[int] | None = None,
        shardings: Any | None = None,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.init_state = init_state
        self.data = data
        self.failure_at = set(failure_at or ())
        self.shardings = shardings
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep,
                                      async_save=cfg.async_save)
        self.guard = PreemptionGuard()
        self.metrics_log: list[dict] = []

    @property
    def _preempted(self) -> bool:
        return self.guard.preempted

    def preempt(self) -> None:
        """Request a clean stop at the next step boundary (chaos/test hook —
        the same path a real SIGTERM takes)."""
        self.guard.trigger()

    def _restore_or_init(self):
        params, opt_state = self.init_state()
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, params, opt_state
        step, (params, opt_state) = self.ckpt.restore(
            (params, opt_state), shardings=self.shardings
        )
        return step + 1, params, opt_state

    def _log(self, rec: dict):
        self.metrics_log.append(rec)
        if self.cfg.log_path:
            with open(self.cfg.log_path, "a") as f:
                f.write(json.dumps(rec) + "\n")

    def run(self) -> dict:
        self.guard.install()
        restarts = 0
        try:
            while True:
                try:
                    return self._run_once(restarts)
                except StepFailure as e:
                    restarts += 1
                    if restarts > self.cfg.max_restarts:
                        raise
                    self._log({"event": "restart", "restarts": restarts,
                               "error": str(e)})
                    obs.event("train.restart", restarts=restarts,
                              error=str(e))
                    obs.inc("train.restarts")
        finally:
            self.guard.restore()

    def _run_once(self, restarts: int) -> dict:
        step, params, opt_state = self._restore_or_init()
        t0 = time.time()
        while step < self.cfg.total_steps:
            if self._preempted:
                # A step-0 preemption has nothing completed to persist; saving
                # step-1 would write an unparseable "step_-000000001" dir that
                # all_steps() can never restore.
                if step > 0:
                    self.ckpt.save(step - 1, (params, opt_state))
                self._log({"event": "preempted", "step": step})
                obs.event("resilience.preempted", step=step)
                return {"status": "preempted", "step": step,
                        "restarts": restarts}
            if step in self.failure_at:
                self.failure_at.discard(step)
                raise StepFailure(f"injected failure at step {step}")
            batch = next(self.data)
            # step_fn may DONATE params/opt_state (REPRO_DONATE, see
            # launch/train.py): after this call only the returned values may
            # be touched. Every read below (checkpoint, preempt-save,
            # metrics) uses the outputs, and CheckpointManager.save
            # host-gathers synchronously before returning, so the next
            # step's donation can never invalidate an in-flight save.
            with obs.span("train.step", step=step):
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
            if step % self.cfg.ckpt_every == 0:
                with obs.span("ckpt.save", step=step):
                    self.ckpt.save(step, (params, opt_state),
                                   block=not self.cfg.async_save)
            self._log({"step": step,
                       **{k: float(v) for k, v in metrics.items()}})
            obs.inc("train.steps")
            step += 1
        self.ckpt.wait()
        self.ckpt.save(self.cfg.total_steps - 1, (params, opt_state))
        return {
            "status": "done",
            "step": step,
            "restarts": restarts,
            "wall_s": time.time() - t0,
            "final_loss": self.metrics_log[-1].get("loss")
            if self.metrics_log else None,
        }
