from repro.runtime.trainer import Trainer, TrainerConfig, StepFailure

__all__ = ["Trainer", "TrainerConfig", "StepFailure"]
