"""Batched serving engine: prefill/decode split + slot-based continuous
batching (vLLM-style at miniature scale, pure JAX).

The engine owns a fixed pool of ``slots`` (the decode batch). Requests are
prefilled one micro-batch at a time (prefill is compute-bound and jitted
separately from decode), their caches inserted into free slots; the decode
step advances every active slot by one token per call. Finished slots
(EOS or max_tokens) are freed and refilled from the queue — decode batches
stay full, which is where decode throughput comes from.

CPU-scale here; the slot logic, cache layout and step functions are the
same ones the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None):
        if cfg.family in ("encdec",):
            raise NotImplementedError("engine covers causal-LM families")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = M.init_cache(cfg, slots, max_len)
        self.pos = np.zeros((slots,), np.int32)        # next position per slot
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []

        self._prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, t, pos, c: M.decode_step(cfg, p, t, pos, c)
        )

    # -- request management ---------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _insert(self, slot: int, req: Request) -> None:
        """Prefill a single request and copy its cache into the slot."""
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache1 = self._prefill(self.params, batch)
        s = len(req.prompt)

        def put(dst, src):
            # dst (n, slots, T, ...) ; src (n, 1, s, ...) — copy the prefix
            # into [slot]; cache layouts beyond attention (state caches) have
            # matching rank and copy wholesale.
            if dst.ndim >= 3 and src.shape[2] <= dst.shape[2]:
                d = dst.at[:, slot : slot + 1, : src.shape[2]].set(src)
                return d
            return dst.at[:, slot : slot + 1].set(src)

        self.caches = jax.tree.map(put, self.caches, cache1)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        self.active[slot] = req
        self.pos[slot] = s
        del tok

    def admit(self) -> int:
        """Move queued requests into free slots. Returns number admitted."""
        n = 0
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            self._insert(slot, self.queue.pop(0))
            n += 1
        return n

    # -- decode ----------------------------------------------------------------

    def step(self) -> int:
        """One decode step for all active slots. Returns #finished."""
        if all(r is None for r in self.active):
            return 0
        last = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.out:
                last[i, 0] = r.out[-1]
        # NOTE: slots decode at a common position index — per-slot positions
        # are handled by masking inside decode (positions beyond pos are
        # zero-filled cache rows attended with ~0 weight after softmax of
        # -inf mask). For simplicity all slots share max(pos); per-slot pos
        # serving needs ragged decode (see DESIGN.md future work).
        pos = int(self.pos.max())
        logits, self.caches = self._decode(
            self.params, jnp.asarray(last), jnp.int32(pos), self.caches
        )
        finished = 0
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = int(jnp.argmax(logits[i, 0]))
            r.out.append(tok)
            self.pos[i] = pos + 1
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(r.out) >= r.max_tokens:
                r.done = True
                self.active[i] = None
                finished += 1
        return finished

    def run(self, requests: list[Request], *, max_steps: int = 1000) -> list[Request]:
        for r in requests:
            self.submit(r)
        done: list[Request] = []
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.admit()
            self.step()
            done.extend(
                [r for r in requests if r.done and r not in done]
            )
            steps += 1
        return requests
