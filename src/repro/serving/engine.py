"""Batched serving engine: prefill/decode split + slot-based continuous
batching (vLLM-style at miniature scale, pure JAX).

The engine owns a fixed pool of ``slots`` (the decode batch). Requests are
prefilled one micro-batch at a time (prefill is compute-bound and jitted
separately from decode), their caches inserted into free slots; the decode
step advances every active slot by one token per call. Finished slots
(EOS or max_tokens) are freed and refilled from the queue — decode batches
stay full, which is where decode throughput comes from.

CPU-scale here; the slot logic, cache layout and step functions are the
same ones the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as M
from repro.models.common import ModelConfig


class AdmissionError(RuntimeError):
    """The bounded admission queue is full — shed load at the edge."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_tokens: int = 16
    deadline_s: float | None = None  # wall budget from submission (None = off)
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    timed_out: bool = False
    submitted_at: float | None = None  # set by ServeEngine.submit
    finished_at: float | None = None   # set by ServeEngine._finish
    latency_s: float | None = None     # enqueue -> completion (engine clock)


@functools.lru_cache(maxsize=None)
def _step_fns(cfg: ModelConfig):
    """One compiled (prefill, decode) pair per model config, shared across
    every engine instance (JH003: a per-instance jit defeats the cache)."""
    prefill = jax.jit(functools.partial(M.prefill, cfg))
    decode = jax.jit(functools.partial(M.decode_step, cfg))
    return prefill, decode


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None,
                 max_queue: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if cfg.family in ("encdec",):
            raise NotImplementedError("engine covers causal-LM families")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.max_queue = max_queue
        self.clock = clock
        self.caches = M.init_cache(cfg, slots, max_len)
        self.pos = np.zeros((slots,), np.int32)        # next position per slot
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self._finished: list[Request] = []  # completion-ordered, drained by run

        self._prefill, self._decode = _step_fns(cfg)

    # -- request management ---------------------------------------------------

    def submit(self, req: Request) -> None:
        """Admit into the bounded queue; raises AdmissionError when full."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            raise AdmissionError(
                f"admission queue full ({self.max_queue} waiting); "
                f"retry with backoff (repro.resilience.retry)"
            )
        req.submitted_at = self.clock()
        self.queue.append(req)
        obs.gauge("serve.queue_depth", len(self.queue))

    def _expired(self, req: Request) -> bool:
        return (
            req.deadline_s is not None
            and req.submitted_at is not None
            and self.clock() - req.submitted_at > req.deadline_s
        )

    def _finish(self, req: Request, *, timed_out: bool = False) -> None:
        req.done = True
        req.timed_out = timed_out
        req.finished_at = self.clock()
        if req.submitted_at is not None:
            req.latency_s = req.finished_at - req.submitted_at
        self._finished.append(req)
        rec = obs.get_recorder()
        if rec is not None:
            rec.inc("serve.timed_out" if timed_out else "serve.completed")
            if req.latency_s is not None:
                rec.observe("serve.request_latency_s", req.latency_s)

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _insert(self, slot: int, req: Request) -> None:
        """Prefill a single request and copy its cache into the slot."""
        with obs.span("serve.prefill", rid=req.rid, slot=slot,
                      prompt_len=len(req.prompt)):
            self._insert_inner(slot, req)

    def _insert_inner(self, slot: int, req: Request) -> None:
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache1 = self._prefill(self.params, batch)
        s = len(req.prompt)

        def put(dst, src):
            # dst (n, slots, T, ...) ; src (n, 1, s, ...) — copy the prefix
            # into [slot]; cache layouts beyond attention (state caches) have
            # matching rank and copy wholesale.
            if dst.ndim >= 3 and src.shape[2] <= dst.shape[2]:
                d = dst.at[:, slot : slot + 1, : src.shape[2]].set(src)
                return d
            return dst.at[:, slot : slot + 1].set(src)

        self.caches = jax.tree.map(put, self.caches, cache1)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        self.active[slot] = req
        self.pos[slot] = s
        del tok

    def admit(self) -> int:
        """Move queued requests into free slots. Returns number admitted.

        Requests whose ``deadline_s`` already elapsed while queued are
        finished as ``timed_out`` instead of wasting a prefill.
        """
        n = 0
        while self.queue:
            if self._expired(self.queue[0]):
                self._finish(self.queue.pop(0), timed_out=True)
                continue
            slot = self._free_slot()
            if slot is None:
                break
            self._insert(slot, self.queue.pop(0))
            n += 1
        if n:
            obs.gauge("serve.queue_depth", len(self.queue))
        return n

    # -- decode ----------------------------------------------------------------

    def step(self) -> int:
        """One decode step for all active slots. Returns #finished."""
        with obs.span("serve.decode",
                      active=sum(r is not None for r in self.active)):
            return self._step_inner()

    def _step_inner(self) -> int:
        finished = 0
        for i, r in enumerate(self.active):
            if r is not None and self._expired(r):
                self._finish(r, timed_out=True)
                self.active[i] = None
                finished += 1
        if all(r is None for r in self.active):
            return finished
        last = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.out:
                last[i, 0] = r.out[-1]
        # NOTE: slots decode at a common position index — per-slot positions
        # are handled by masking inside decode (positions beyond pos are
        # zero-filled cache rows attended with ~0 weight after softmax of
        # -inf mask). For simplicity all slots share max(pos); per-slot pos
        # serving needs ragged decode (see DESIGN.md future work).
        pos = int(self.pos.max())
        logits, self.caches = self._decode(
            self.params, jnp.asarray(last), jnp.int32(pos), self.caches
        )
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = int(jnp.argmax(logits[i, 0]))
            r.out.append(tok)
            self.pos[i] = pos + 1
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(r.out) >= r.max_tokens:
                self._finish(r)
                self.active[i] = None
                finished += 1
        return finished

    def run(self, requests: list[Request], *, max_steps: int = 1000) -> list[Request]:
        """Drive submitted requests to completion; returns them in the order
        they finished (completed or timed out)."""
        with obs.span("serve.run", requests=len(requests)):
            for r in requests:
                self.submit(r)
            done: list[Request] = []
            steps = 0
            while (self.queue or any(self.active)) and steps < max_steps:
                self.admit()
                self.step()
                # Completion order comes from the engine's _finished log — an
                # O(done) drain, not an O(n^2) rescan of every request per
                # step.
                if self._finished:
                    done.extend(self._finished)
                    self._finished.clear()
                steps += 1
            if self._finished:
                done.extend(self._finished)
                self._finished.clear()
            return done
